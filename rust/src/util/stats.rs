//! Summary statistics for the bench harness and metrics reporting.

/// Summary of a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Median absolute deviation (robust spread).
    pub mad: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            mad: percentile_sorted(&devs, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for normalized-runtime aggregation, like Fig 4).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    assert!(xs.iter().all(|&x| x > 0.0));
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_simple() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert!((s.mad - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_singleton() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
