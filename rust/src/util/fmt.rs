//! Human-readable formatting for report/bench output.

/// Format a count with SI suffixes: 1_500_000 -> "1.50M".
pub fn si(x: f64) -> String {
    let (v, suf) = if x.abs() >= 1e12 {
        (x / 1e12, "T")
    } else if x.abs() >= 1e9 {
        (x / 1e9, "G")
    } else if x.abs() >= 1e6 {
        (x / 1e6, "M")
    } else if x.abs() >= 1e3 {
        (x / 1e3, "K")
    } else {
        (x, "")
    };
    if suf.is_empty() {
        format!("{v:.0}")
    } else {
        format!("{v:.2}{suf}")
    }
}

/// Format a duration in seconds adaptively: "1.23s", "45.6ms", "789us".
pub fn secs(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3}s")
    } else if t >= 1e-3 {
        format!("{:.2}ms", t * 1e3)
    } else if t >= 1e-6 {
        format!("{:.1}us", t * 1e6)
    } else {
        format!("{:.0}ns", t * 1e9)
    }
}

/// Format bytes: "1.50 GiB".
pub fn bytes(b: f64) -> String {
    const KIB: f64 = 1024.0;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

/// A fixed-width left-aligned cell, for table printing.
pub fn cell(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(w - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn si_suffixes() {
        assert_eq!(si(950.0), "950");
        assert_eq!(si(1500.0), "1.50K");
        assert_eq!(si(1_500_000.0), "1.50M");
        assert_eq!(si(2.5e9), "2.50G");
        assert_eq!(si(3.2e12), "3.20T");
    }

    #[test]
    fn secs_ranges() {
        assert_eq!(secs(1.5), "1.500s");
        assert_eq!(secs(0.0456), "45.60ms");
        assert_eq!(secs(789e-6), "789.0us");
        assert_eq!(secs(5e-9), "5ns");
    }

    #[test]
    fn bytes_ranges() {
        assert_eq!(bytes(512.0), "512 B");
        assert_eq!(bytes(2048.0), "2.00 KiB");
        assert_eq!(bytes(1024.0 * 1024.0 * 1.5), "1.50 MiB");
    }

    #[test]
    fn cell_pads() {
        assert_eq!(cell("ab", 4), "ab  ");
        assert_eq!(cell("abcdef", 4), "abcdef");
    }
}
