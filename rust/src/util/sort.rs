//! Counting / radix sorting utilities used by graph construction.
//!
//! CSR construction is a counting sort of edges by source; the PNG layout
//! is a counting sort of edges by (partition(dst), src). Both are built on
//! the histogram/prefix-sum helpers here.

/// Exclusive prefix sum; returns the total.
pub fn exclusive_prefix_sum(xs: &mut [u64]) -> u64 {
    let mut acc = 0u64;
    for x in xs.iter_mut() {
        let v = *x;
        *x = acc;
        acc += v;
    }
    acc
}

/// Histogram of `keys` with `n_buckets` buckets.
pub fn histogram(keys: impl Iterator<Item = usize>, n_buckets: usize) -> Vec<u64> {
    let mut h = vec![0u64; n_buckets];
    for k in keys {
        debug_assert!(k < n_buckets);
        h[k] += 1;
    }
    h
}

/// Stable counting sort of `items` by `key(item) < n_buckets`.
/// Returns `(sorted_items, bucket_offsets)` where `bucket_offsets` has
/// `n_buckets + 1` entries (CSR-style).
pub fn counting_sort_by_key<T: Copy, F: Fn(&T) -> usize>(
    items: &[T],
    n_buckets: usize,
    key: F,
) -> (Vec<T>, Vec<u64>) {
    let mut offsets = histogram(items.iter().map(|it| key(it)), n_buckets);
    offsets.push(0);
    let total = exclusive_prefix_sum(&mut offsets[..n_buckets]);
    offsets[n_buckets] = total;
    let mut cursor = offsets[..n_buckets].to_vec();
    let mut out: Vec<T> = Vec::with_capacity(items.len());
    // SAFETY: every slot in 0..items.len() is written exactly once below
    // (cursors partition the output range), after which we set the length.
    unsafe {
        out.set_len(items.len());
    }
    for it in items {
        let k = key(it);
        out[cursor[k] as usize] = *it;
        cursor[k] += 1;
    }
    (out, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_sum() {
        let mut xs = vec![3, 0, 2, 5];
        let total = exclusive_prefix_sum(&mut xs);
        assert_eq!(xs, vec![0, 3, 3, 5]);
        assert_eq!(total, 10);
    }

    #[test]
    fn histogram_counts() {
        let h = histogram([0usize, 2, 2, 3].into_iter(), 4);
        assert_eq!(h, vec![1, 0, 2, 1]);
    }

    #[test]
    fn counting_sort_stable() {
        // (key, payload) — payloads must keep insertion order per key.
        let items = [(2u32, 'a'), (0, 'b'), (2, 'c'), (1, 'd'), (0, 'e')];
        let (sorted, offs) = counting_sort_by_key(&items, 3, |it| it.0 as usize);
        assert_eq!(
            sorted,
            vec![(0, 'b'), (0, 'e'), (1, 'd'), (2, 'a'), (2, 'c')]
        );
        assert_eq!(offs, vec![0, 2, 3, 5]);
    }

    #[test]
    fn counting_sort_empty() {
        let items: [(u32, u32); 0] = [];
        let (sorted, offs) = counting_sort_by_key(&items, 3, |it| it.0 as usize);
        assert!(sorted.is_empty());
        assert_eq!(offs, vec![0, 0, 0, 0]);
    }
}
