//! Graph storage substrate: CSR/CSC (paper §2 "Graph Storage"),
//! construction, generators and IO.

pub mod builder;
pub mod csr;
pub mod gen;
pub mod io;

pub use builder::{merge_delta, permute_graph, GraphBuilder, GraphDelta};
pub use csr::{Csr, Graph};

use crate::VertexId;

/// A directed edge with optional unit weight semantics; generators and IO
/// traffic in plain `(src, dst, weight)` triples.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub src: VertexId,
    pub dst: VertexId,
    pub weight: f32,
}

impl Edge {
    pub fn new(src: VertexId, dst: VertexId) -> Self {
        Self { src, dst, weight: 1.0 }
    }

    pub fn weighted(src: VertexId, dst: VertexId, weight: f32) -> Self {
        Self { src, dst, weight }
    }
}
