//! Graph IO: whitespace edge-list text (optionally weighted), a compact
//! binary CSR format for fast reloads, and edge-delta files for
//! streaming ingestion (`gpop ingest`).

use super::builder::{GraphBuilder, GraphDelta};
use super::csr::{Csr, Graph};
use crate::VertexId;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GPOPCSR1";

/// Parse an edge-list text file: one `src dst [weight]` per line;
/// `#`/`%`-prefixed lines are comments.
pub fn read_edge_list(path: &Path) -> std::io::Result<Graph> {
    let f = File::open(path)?;
    let mut b = GraphBuilder::new();
    let mut weighted_any = false;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        fn missing(lineno: usize, what: &str) -> std::io::Error {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: missing {what}", lineno + 1),
            )
        }
        let src: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "src"))?
            .parse()
            .map_err(bad_data(lineno))?;
        let dst: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "dst"))?
            .parse()
            .map_err(bad_data(lineno))?;
        match it.next() {
            Some(w) => {
                weighted_any = true;
                b.add_weighted(src, dst, w.parse().map_err(bad_data(lineno))?);
            }
            None => {
                b.add(src, dst);
            }
        }
    }
    let _ = weighted_any;
    Ok(b.build())
}

fn bad_data<E: std::fmt::Display>(lineno: usize) -> impl Fn(E) -> std::io::Error {
    move |e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {}: {e}", lineno + 1))
    }
}

/// Write an edge-list text file (weights included if present).
pub fn write_edge_list(g: &Graph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let csr = g.out();
    for v in 0..g.n() as VertexId {
        let ws = csr.edge_weights(v);
        for (k, &u) in csr.neighbors(v).iter().enumerate() {
            match ws {
                Some(ws) => writeln!(w, "{v} {u} {}", ws[k])?,
                None => writeln!(w, "{v} {u}")?,
            }
        }
    }
    Ok(())
}

/// Parse an edge-delta text file for streaming ingestion. One update
/// per line:
///
/// - `+ src dst [weight]` — insert (bare `src dst [weight]` lines are
///   inserts too, so a plain edge list is a valid all-insert delta)
/// - `- src dst` — delete every parallel `src -> dst` edge
///
/// `#`/`%`-prefixed lines are comments. Endpoint validation against a
/// concrete graph happens at merge time
/// ([`merge_delta`](super::builder::merge_delta)), not here.
pub fn read_delta(path: &Path) -> std::io::Result<GraphDelta> {
    let f = File::open(path)?;
    let mut delta = GraphDelta::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let (op, rest) = match t.strip_prefix('+') {
            Some(r) => ('+', r),
            None => match t.strip_prefix('-') {
                Some(r) => ('-', r),
                None => ('+', t),
            },
        };
        let mut it = rest.split_whitespace();
        fn missing(lineno: usize, what: &str) -> std::io::Error {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: missing {what}", lineno + 1),
            )
        }
        let src: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "src"))?
            .parse()
            .map_err(bad_data(lineno))?;
        let dst: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "dst"))?
            .parse()
            .map_err(bad_data(lineno))?;
        match (op, it.next()) {
            ('-', Some(extra)) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("line {}: delete lines take no weight (got {extra:?})", lineno + 1),
                ));
            }
            ('-', None) => {
                delta.delete(src, dst);
            }
            (_, Some(w)) => {
                delta.insert_weighted(src, dst, w.parse().map_err(bad_data(lineno))?);
                if let Some(extra) = it.next() {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!(
                            "line {}: trailing tokens after the weight (got {extra:?})",
                            lineno + 1
                        ),
                    ));
                }
            }
            (_, None) => {
                delta.insert(src, dst);
            }
        }
    }
    Ok(delta)
}

/// Write an edge-delta file readable by [`read_delta`].
pub fn write_delta(delta: &GraphDelta, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for e in delta.inserts() {
        if e.weight == 1.0 {
            writeln!(w, "+ {} {}", e.src, e.dst)?;
        } else {
            writeln!(w, "+ {} {} {}", e.src, e.dst, e.weight)?;
        }
    }
    for &(s, d) in delta.deletes() {
        writeln!(w, "- {s} {d}")?;
    }
    Ok(())
}

/// Binary CSR: magic, n, m, has_weights, offsets[u64], targets[u32],
/// weights[f32] (little-endian).
pub fn write_binary(g: &Graph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let csr = g.out();
    w.write_all(MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.m() as u64).to_le_bytes())?;
    w.write_all(&[u8::from(csr.is_weighted())])?;
    for &o in csr.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &t in csr.targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    if let Some(ws) = csr.weights() {
        for &x in ws {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read a binary CSR, treating the file as *untrusted*: header counts
/// are validated against the actual file size **before** any allocation
/// (a truncated or corrupt header cannot demand a multi-GiB buffer),
/// and the payload is structurally validated (monotone offsets ending
/// at `m`, every target `< n`). Any violation is an
/// [`std::io::ErrorKind::InvalidData`] error, never a panic or abort.
///
/// Peak memory is the output arrays plus one fixed
/// [`DECODE_CHUNK_BYTES`] scratch buffer: each section streams through
/// it in bounded chunks ([`read_section`]), so the transient overhead
/// is constant regardless of file size — what the out-of-core path
/// ([`crate::ooc`]) needs from its only full-file fallback reader.
pub fn read_binary(path: &Path) -> std::io::Result<Graph> {
    fn bad(msg: String) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
    }
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad magic".into()));
    }
    let n = read_u64(&mut r)?;
    let m = read_u64(&mut r)?;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    if flag[0] > 1 {
        return Err(bad(format!("weight flag must be 0 or 1 (got {})", flag[0])));
    }
    let weighted = flag[0] == 1;
    if n > u32::MAX as u64 {
        return Err(bad(format!("vertex count {n} exceeds the u32 id space")));
    }
    // Header + (n+1) u64 offsets + m u32 targets (+ m f32 weights).
    let header = 8u64 + 8 + 8 + 1;
    let per_edge = if weighted { 8u64 } else { 4 };
    let expected = n
        .checked_add(1)
        .and_then(|x| x.checked_mul(8))
        .and_then(|x| x.checked_add(header))
        .and_then(|x| m.checked_mul(per_edge).and_then(|y| x.checked_add(y)))
        .ok_or_else(|| bad(format!("header counts overflow (n={n}, m={m})")))?;
    if expected != file_len {
        return Err(bad(format!(
            "file is {file_len} bytes but header (n={n}, m={m}, weighted={weighted}) \
             implies {expected} — truncated or corrupt"
        )));
    }
    let (n, m) = (n as usize, m as usize);
    let mut offsets = vec![0u64; n + 1];
    read_section(&mut r, &mut offsets, |b| Ok(u64::from_le_bytes(b)))?;
    if offsets[0] != 0 {
        return Err(bad(format!("offsets[0] must be 0 (got {})", offsets[0])));
    }
    if offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(bad("offsets are not monotone non-decreasing".into()));
    }
    if offsets[n] != m as u64 {
        return Err(bad(format!("offsets[n] = {} but header says m = {m}", offsets[n])));
    }
    let mut targets = vec![0 as VertexId; m];
    read_section(&mut r, &mut targets, |b| {
        let v = u32::from_le_bytes(b);
        if v as u64 >= n as u64 {
            return Err(bad(format!("edge target {v} out of range (n = {n})")));
        }
        Ok(v)
    })?;
    let weights = if weighted {
        let mut ws = vec![0f32; m];
        read_section(&mut r, &mut ws, |b| Ok(f32::from_le_bytes(b)))?;
        Some(ws)
    } else {
        None
    };
    Ok(Graph::from_csr(Csr::new(n, offsets, targets, weights)))
}

/// Scratch size for [`read_section`]: large enough to amortize the
/// per-chunk decode loop, small enough that [`read_binary`]'s transient
/// memory is a rounding error next to the arrays it fills.
const DECODE_CHUNK_BYTES: usize = 64 * 1024;

/// Fill `out` with `W`-byte little-endian elements streamed from `r`
/// through a bounded scratch buffer, applying `decode` to each — the
/// chunked alternative to one `read_exact` call per element. `decode`
/// may reject a value (e.g. an out-of-range edge target), failing the
/// whole read.
fn read_section<R: Read, T, const W: usize>(
    r: &mut R,
    out: &mut [T],
    mut decode: impl FnMut([u8; W]) -> std::io::Result<T>,
) -> std::io::Result<()> {
    debug_assert!(W > 0 && DECODE_CHUNK_BYTES % W == 0, "chunk must hold whole elements");
    let mut scratch = vec![0u8; DECODE_CHUNK_BYTES.min(out.len() * W)];
    let mut rest = out;
    while !rest.is_empty() {
        let take = rest.len().min(scratch.len() / W);
        let buf = &mut scratch[..take * W];
        r.read_exact(buf)?;
        let (head, tail) = rest.split_at_mut(take);
        for (slot, chunk) in head.iter_mut().zip(buf.chunks_exact(W)) {
            let mut b = [0u8; W];
            b.copy_from_slice(chunk);
            *slot = decode(b)?;
        }
        rest = tail;
    }
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gpop_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::erdos_renyi(100, 400, 11);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g2.m(), g.m());
        assert_eq!(g2.out().targets(), g.out().targets());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_weighted_and_comments() {
        let p = tmp("wel.txt");
        std::fs::write(&p, "# comment\n0 1 2.5\n% other\n1 2 3.5\n\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.m(), 2);
        assert!(g.is_weighted());
        assert_eq!(g.out().edge_weights(0).unwrap(), &[2.5]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_bad_line_errors() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 notanumber\n").unwrap();
        assert!(read_edge_list(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn delta_file_roundtrip_and_bare_lines() {
        let p = tmp("delta.el");
        std::fs::write(&p, "# adds\n+ 0 1\n7 8 2.5\n- 3 4\n% done\n").unwrap();
        let d = read_delta(&p).unwrap();
        assert_eq!(d.inserts().len(), 2, "bare lines are inserts");
        assert_eq!((d.inserts()[0].src, d.inserts()[0].dst), (0, 1));
        assert_eq!(d.inserts()[1].weight, 2.5);
        assert_eq!(d.deletes(), &[(3, 4)]);
        write_delta(&d, &p).unwrap();
        let d2 = read_delta(&p).unwrap();
        assert_eq!(d2.inserts().len(), 2);
        assert_eq!(d2.inserts()[1].weight, 2.5);
        assert_eq!(d2.deletes(), &[(3, 4)]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn delta_file_bad_lines_rejected() {
        for (name, body) in [
            ("d1", "+ 0\n"),
            ("d2", "- 1 2 3.5\n"),
            ("d3", "+ x 1\n"),
            ("d4", "0 1 notaw\n"),
            ("d5", "+ 0 1 2 3\n"),
        ] {
            let p = tmp(&format!("delta_{name}"));
            std::fs::write(&p, body).unwrap();
            let err = read_delta(&p).expect_err(name);
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{name}");
            std::fs::remove_file(&p).unwrap();
        }
    }

    #[test]
    fn binary_roundtrip_unweighted() {
        let g = gen::rmat(8, Default::default(), false);
        let p = tmp("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g2, g, "write_binary → read_binary must reproduce the graph exactly");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_roundtrip_weighted() {
        let g = gen::with_uniform_weights(&gen::chain(50), 1.0, 2.0, 5);
        let p = tmp("gw.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert!(g2.is_weighted());
        assert_eq!(g2, g, "weighted roundtrip must reproduce weights bit-for-bit");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_roundtrip_isolated_vertices_and_empty() {
        // Zero-degree tails and the empty graph exercise the offsets
        // edge cases of both writer and validator.
        let mut b = crate::graph::GraphBuilder::new().with_n(10);
        b.add(0, 9).add(3, 3);
        let sparse = b.build();
        let empty = crate::graph::builder::graph_from_edges(0, &[]);
        for (g, name) in [(sparse, "sparse"), (empty, "empty")] {
            let p = tmp(name);
            write_binary(&g, &p).unwrap();
            assert_eq!(read_binary(&p).unwrap(), g, "{name}");
            std::fs::remove_file(&p).unwrap();
        }
    }

    #[test]
    fn binary_roundtrip_spans_many_decode_chunks() {
        // (n+1)*8 and m*4 both exceed DECODE_CHUNK_BYTES, so every
        // section takes the multi-chunk path of read_section, including
        // a final partial chunk.
        let g = gen::with_uniform_weights(&gen::erdos_renyi(20_000, 50_000, 23), 0.5, 2.0, 9);
        assert!((g.n() + 1) * 8 > DECODE_CHUNK_BYTES);
        assert!(g.m() * 4 > DECODE_CHUNK_BYTES);
        let p = tmp("chunks.bin");
        write_binary(&g, &p).unwrap();
        assert_eq!(read_binary(&p).unwrap(), g);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn graph_equality_ignores_the_csc_cache() {
        let g = gen::erdos_renyi(50, 200, 3);
        let mut with_csc = g.clone();
        with_csc.ensure_csc();
        assert_eq!(with_csc, g, "materializing the CSC must not change identity");
        assert_ne!(g, gen::erdos_renyi(50, 200, 4));
    }

    #[test]
    fn binary_bad_magic() {
        let p = tmp("badmagic.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    /// Write a valid file, apply `corrupt` to its bytes, and expect
    /// `InvalidData` (not a panic, not an abort, not a giant alloc).
    fn expect_invalid(name: &str, corrupt: impl FnOnce(&mut Vec<u8>)) {
        let g = gen::erdos_renyi(60, 300, 13);
        let p = tmp(name);
        write_binary(&g, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        corrupt(&mut bytes);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary(&p).expect_err(name);
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{name}: {err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_truncated_file_rejected() {
        expect_invalid("trunc.bin", |b| {
            let keep = b.len() - 10;
            b.truncate(keep);
        });
    }

    #[test]
    fn binary_oversized_vertex_count_rejected() {
        // A tiny file whose header demands a multi-GiB offsets array
        // must be rejected BEFORE allocating (this aborted pre-fix).
        expect_invalid("huge_n.bin", |b| {
            b[8..16].copy_from_slice(&(1u64 << 40).to_le_bytes());
        });
        // n beyond the u32 id space is invalid even if sizes matched.
        expect_invalid("u32_overflow_n.bin", |b| {
            b[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        });
    }

    #[test]
    fn binary_non_monotone_offsets_rejected() {
        // offsets start right after the 25-byte header; make the second
        // entry larger than the third.
        expect_invalid("nonmono.bin", |b| {
            b[25 + 8..25 + 16].copy_from_slice(&u32::MAX.to_le_bytes().repeat(2));
        });
    }

    #[test]
    fn binary_out_of_range_target_rejected() {
        expect_invalid("badtarget.bin", |b| {
            let g_n = 60u64;
            // First target lives after header + (n+1) offsets.
            let pos = 25 + (g_n as usize + 1) * 8;
            b[pos..pos + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        });
    }

    #[test]
    fn binary_bad_weight_flag_rejected() {
        expect_invalid("badflag.bin", |b| {
            b[24] = 7;
        });
    }

    #[test]
    fn binary_mismatched_edge_total_rejected() {
        // offsets[n] != m: grow the last offset while keeping monotone.
        expect_invalid("edgetotal.bin", |b| {
            let g_n = 60usize;
            let pos = 25 + g_n * 8; // offsets[n]
            let mut last = [0u8; 8];
            last.copy_from_slice(&b[pos..pos + 8]);
            let v = u64::from_le_bytes(last) + 1;
            b[pos..pos + 8].copy_from_slice(&v.to_le_bytes());
        });
    }
}
