//! Graph IO: whitespace edge-list text (optionally weighted) and a
//! compact binary CSR format for fast reloads.

use super::builder::GraphBuilder;
use super::csr::{Csr, Graph};
use crate::VertexId;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GPOPCSR1";

/// Parse an edge-list text file: one `src dst [weight]` per line;
/// `#`/`%`-prefixed lines are comments.
pub fn read_edge_list(path: &Path) -> std::io::Result<Graph> {
    let f = File::open(path)?;
    let mut b = GraphBuilder::new();
    let mut weighted_any = false;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        fn missing(lineno: usize, what: &str) -> std::io::Error {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: missing {what}", lineno + 1),
            )
        }
        let src: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "src"))?
            .parse()
            .map_err(bad_data(lineno))?;
        let dst: VertexId = it
            .next()
            .ok_or_else(|| missing(lineno, "dst"))?
            .parse()
            .map_err(bad_data(lineno))?;
        match it.next() {
            Some(w) => {
                weighted_any = true;
                b.add_weighted(src, dst, w.parse().map_err(bad_data(lineno))?);
            }
            None => {
                b.add(src, dst);
            }
        }
    }
    let _ = weighted_any;
    Ok(b.build())
}

fn bad_data<E: std::fmt::Display>(lineno: usize) -> impl Fn(E) -> std::io::Error {
    move |e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("line {}: {e}", lineno + 1))
    }
}

/// Write an edge-list text file (weights included if present).
pub fn write_edge_list(g: &Graph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let csr = g.out();
    for v in 0..g.n() as VertexId {
        let ws = csr.edge_weights(v);
        for (k, &u) in csr.neighbors(v).iter().enumerate() {
            match ws {
                Some(ws) => writeln!(w, "{v} {u} {}", ws[k])?,
                None => writeln!(w, "{v} {u}")?,
            }
        }
    }
    Ok(())
}

/// Binary CSR: magic, n, m, has_weights, offsets[u64], targets[u32],
/// weights[f32] (little-endian).
pub fn write_binary(g: &Graph, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    let csr = g.out();
    w.write_all(MAGIC)?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.m() as u64).to_le_bytes())?;
    w.write_all(&[u8::from(csr.is_weighted())])?;
    for &o in csr.offsets() {
        w.write_all(&o.to_le_bytes())?;
    }
    for &t in csr.targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    if let Some(ws) = csr.weights() {
        for &x in ws {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_binary(path: &Path) -> std::io::Result<Graph> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "bad magic"));
    }
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let mut offsets = vec![0u64; n + 1];
    for o in offsets.iter_mut() {
        *o = read_u64(&mut r)?;
    }
    let mut targets = vec![0 as VertexId; m];
    for t in targets.iter_mut() {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        *t = u32::from_le_bytes(b);
    }
    let weights = if flag[0] == 1 {
        let mut ws = vec![0f32; m];
        for x in ws.iter_mut() {
            let mut b = [0u8; 4];
            r.read_exact(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        Some(ws)
    } else {
        None
    };
    Ok(Graph::from_csr(Csr::new(n, offsets, targets, weights)))
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gpop_io_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::erdos_renyi(100, 400, 11);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p).unwrap();
        assert_eq!(g2.m(), g.m());
        assert_eq!(g2.out().targets(), g.out().targets());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_weighted_and_comments() {
        let p = tmp("wel.txt");
        std::fs::write(&p, "# comment\n0 1 2.5\n% other\n1 2 3.5\n\n").unwrap();
        let g = read_edge_list(&p).unwrap();
        assert_eq!(g.m(), 2);
        assert!(g.is_weighted());
        assert_eq!(g.out().edge_weights(0).unwrap(), &[2.5]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn edge_list_bad_line_errors() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 notanumber\n").unwrap();
        assert!(read_edge_list(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_roundtrip_unweighted() {
        let g = gen::rmat(8, Default::default(), false);
        let p = tmp("g.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g2.n(), g.n());
        assert_eq!(g2.out().offsets(), g.out().offsets());
        assert_eq!(g2.out().targets(), g.out().targets());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_roundtrip_weighted() {
        let g = gen::with_uniform_weights(&gen::chain(50), 1.0, 2.0, 5);
        let p = tmp("gw.bin");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert!(g2.is_weighted());
        assert_eq!(g2.out().weights().unwrap(), g.out().weights().unwrap());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn binary_bad_magic() {
        let p = tmp("badmagic.bin");
        std::fs::write(&p, b"NOTMAGIC........").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
