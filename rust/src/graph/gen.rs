//! Synthetic graph generators.
//!
//! The paper's scaling studies (Figs 5–8) use RMAT graphs "with default
//! settings (scale-free graphs) and degree 16" — i.e. the Graph500
//! parameters a=0.57, b=0.19, c=0.19, d=0.05, edge factor 16. We also
//! provide Erdős–Rényi (uniform) graphs, chains/grids for tests, and a
//! power-law "web-like" generator for the example workloads.

use super::builder::GraphBuilder;
use super::csr::Graph;
use super::Edge;
use crate::exec::ThreadPool;
use crate::util::rng::Rng;
use crate::VertexId;

/// Edges drawn per RNG chunk. The random generators draw chunk `c` from
/// its own `Rng::stream(seed, c)`, so the edge list is identical whether
/// chunks are generated serially or on a pool — and independent of the
/// pool's thread count (pinned by `tests/preprocess.rs`).
const GEN_CHUNK: usize = 1 << 16;

/// Generate `m` edges in deterministic RNG chunks, optionally in
/// parallel.
fn gen_edges<F: Fn(&mut Rng) -> Edge + Sync>(
    m: usize,
    seed: u64,
    pool: Option<&mut ThreadPool>,
    f: F,
) -> Vec<Vec<Edge>> {
    let n_chunks = crate::util::div_ceil(m, GEN_CHUNK);
    let gen_one = |c: usize| {
        let lo = c * GEN_CHUNK;
        let hi = (lo + GEN_CHUNK).min(m);
        let mut rng = Rng::stream(seed, c as u64);
        (lo..hi).map(|_| f(&mut rng)).collect::<Vec<Edge>>()
    };
    match pool {
        Some(p) if p.n_threads() > 1 => p.map_parts(n_chunks, gen_one),
        _ => (0..n_chunks).map(gen_one).collect(),
    }
}

/// Graph500 RMAT parameters.
#[derive(Clone, Copy, Debug)]
pub struct RmatParams {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Edges per vertex (paper: 16).
    pub edge_factor: usize,
    pub seed: u64,
}

impl Default for RmatParams {
    fn default() -> Self {
        Self { a: 0.57, b: 0.19, c: 0.19, edge_factor: 16, seed: 0x9a0e_1234 }
    }
}

/// Generate an RMAT graph of `2^scale` vertices. Self-loops are dropped
/// and adjacency lists are sorted; parallel edges are kept (as Graph500
/// does) unless `dedup`.
pub fn rmat(scale: u32, params: RmatParams, dedup: bool) -> Graph {
    rmat_impl(scale, params, dedup, None)
}

/// [`rmat`] with edge generation and CSR construction parallelized over
/// `pool`; the resulting graph is identical to the serial one.
pub fn rmat_par(scale: u32, params: RmatParams, dedup: bool, pool: &mut ThreadPool) -> Graph {
    rmat_impl(scale, params, dedup, Some(pool))
}

fn rmat_impl(
    scale: u32,
    params: RmatParams,
    dedup: bool,
    mut pool: Option<&mut ThreadPool>,
) -> Graph {
    let n = 1usize << scale;
    let m = n * params.edge_factor;
    let mut b = GraphBuilder::new().with_n(n).drop_self_loops();
    if dedup {
        b = b.dedup();
    }
    let chunks = gen_edges(m, params.seed, pool.as_mut().map(|p| &mut **p), |rng| {
        rmat_edge(scale, &params, rng)
    });
    for chunk in chunks {
        b.extend(chunk);
    }
    match pool {
        Some(p) => b.build_with_pool(p),
        None => b.build(),
    }
}

fn rmat_edge(scale: u32, p: &RmatParams, rng: &mut Rng) -> Edge {
    let mut src = 0u64;
    let mut dst = 0u64;
    for _ in 0..scale {
        src <<= 1;
        dst <<= 1;
        let r = rng.next_f64();
        if r < p.a {
            // top-left: no bits set
        } else if r < p.a + p.b {
            dst |= 1;
        } else if r < p.a + p.b + p.c {
            src |= 1;
        } else {
            src |= 1;
            dst |= 1;
        }
    }
    Edge::new(src as VertexId, dst as VertexId)
}

/// Erdős–Rényi G(n, m): m uniform random directed edges.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Graph {
    erdos_renyi_impl(n, m, seed, None)
}

/// [`erdos_renyi`] with edge generation and CSR construction
/// parallelized over `pool`; the resulting graph is identical to the
/// serial one.
pub fn erdos_renyi_par(n: usize, m: usize, seed: u64, pool: &mut ThreadPool) -> Graph {
    erdos_renyi_impl(n, m, seed, Some(pool))
}

fn erdos_renyi_impl(n: usize, m: usize, seed: u64, mut pool: Option<&mut ThreadPool>) -> Graph {
    let mut b = GraphBuilder::new().with_n(n).drop_self_loops();
    let chunks = gen_edges(m, seed, pool.as_mut().map(|p| &mut **p), |rng| {
        let s = rng.below(n as u64) as VertexId;
        let d = rng.below(n as u64) as VertexId;
        Edge::new(s, d)
    });
    for chunk in chunks {
        b.extend(chunk);
    }
    match pool {
        Some(p) => b.build_with_pool(p),
        None => b.build(),
    }
}

/// A directed chain 0 -> 1 -> ... -> n-1 (worst-case diameter; exercises
/// many tiny frontiers).
pub fn chain(n: usize) -> Graph {
    let mut b = GraphBuilder::new().with_n(n);
    for v in 0..n.saturating_sub(1) {
        b.add(v as VertexId, v as VertexId + 1);
    }
    b.build()
}

/// A 2-D grid with 4-neighborhood, symmetrized (rows × cols vertices).
pub fn grid(rows: usize, cols: usize) -> Graph {
    let mut b = GraphBuilder::new().with_n(rows * cols).symmetrize();
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                b.add(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                b.add(id(r, c), id(r + 1, c));
            }
        }
    }
    b.build()
}

/// Symmetrized copy of a graph: every edge gets its mirror (weights
/// dropped — the symmetric workloads are structural: connected
/// components, k-core). Multiplicities are kept, like the generators.
pub fn symmetrized(g: &Graph) -> Graph {
    let csr = g.out();
    let mut b = GraphBuilder::new().with_n(g.n()).symmetrize();
    for v in 0..g.n() as VertexId {
        for &u in csr.neighbors(v) {
            b.add(v, u);
        }
    }
    b.build()
}

/// Assign uniform random weights in `[lo, hi)` to an unweighted graph
/// (for SSSP workloads), deterministically from `seed`.
pub fn with_uniform_weights(g: &Graph, lo: f32, hi: f32, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let csr = g.out();
    let mut b = GraphBuilder::new().with_n(g.n()).weighted();
    for v in 0..g.n() as VertexId {
        for &u in csr.neighbors(v) {
            b.add_weighted(v, u, lo + rng.next_f32() * (hi - lo));
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetrized_mirrors_every_edge() {
        let g = rmat(7, RmatParams::default(), false);
        let s = symmetrized(&g);
        assert_eq!(s.m(), 2 * g.m(), "every edge gains a mirror");
        for v in 0..g.n() as VertexId {
            for &u in g.out().neighbors(v) {
                assert!(s.out().neighbors(u).contains(&v), "missing mirror {u}->{v}");
                assert!(s.out().neighbors(v).contains(&u), "missing original {v}->{u}");
            }
        }
        assert!(!s.is_weighted());
    }

    #[test]
    fn rmat_shape() {
        let g = rmat(10, RmatParams::default(), false);
        assert_eq!(g.n(), 1024);
        // Self-loops dropped, so m <= n * 16.
        assert!(g.m() <= 1024 * 16);
        assert!(g.m() > 1024 * 12, "most RMAT edges should survive");
    }

    #[test]
    fn parallel_generators_match_serial() {
        for t in [1usize, 2, 4] {
            let mut pool = ThreadPool::new(t);
            let a = rmat(9, RmatParams::default(), false);
            let b = rmat_par(9, RmatParams::default(), false, &mut pool);
            assert_eq!(a.out().offsets(), b.out().offsets(), "rmat offsets, t={t}");
            assert_eq!(a.out().targets(), b.out().targets(), "rmat targets, t={t}");
            let a = erdos_renyi(700, 5000, 3);
            let b = erdos_renyi_par(700, 5000, 3, &mut pool);
            assert_eq!(a.out().offsets(), b.out().offsets(), "er offsets, t={t}");
            assert_eq!(a.out().targets(), b.out().targets(), "er targets, t={t}");
        }
    }

    #[test]
    fn rmat_deterministic() {
        let a = rmat(8, RmatParams::default(), false);
        let b = rmat(8, RmatParams::default(), false);
        assert_eq!(a.out().targets(), b.out().targets());
        let c = rmat(8, RmatParams { seed: 7, ..Default::default() }, false);
        assert_ne!(a.out().targets(), c.out().targets());
    }

    #[test]
    fn rmat_is_skewed() {
        // Scale-free: max degree far above mean.
        let g = rmat(12, RmatParams::default(), false);
        let (max, mean, _) = g.degree_stats();
        assert!(max as f64 > 8.0 * mean, "max {max} vs mean {mean}");
    }

    #[test]
    fn er_shape() {
        let g = erdos_renyi(1000, 5000, 1);
        assert_eq!(g.n(), 1000);
        assert!(g.m() <= 5000 && g.m() > 4900); // few self-loops dropped
    }

    #[test]
    fn chain_shape() {
        let g = chain(5);
        assert_eq!(g.m(), 4);
        assert_eq!(g.out().neighbors(0), &[1]);
        assert_eq!(g.out().neighbors(4), &[] as &[u32]);
    }

    #[test]
    fn grid_shape() {
        let g = grid(3, 3);
        assert_eq!(g.n(), 9);
        assert_eq!(g.m(), 24); // 12 undirected edges
        assert_eq!(g.out_degree(4), 4); // center has 4 neighbors
    }

    #[test]
    fn uniform_weights_in_range() {
        let g = with_uniform_weights(&chain(100), 1.0, 5.0, 3);
        assert!(g.is_weighted());
        for v in 0..99u32 {
            for &w in g.out().edge_weights(v).unwrap() {
                assert!((1.0..5.0).contains(&w));
            }
        }
    }
}
