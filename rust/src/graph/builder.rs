//! Edge-list → CSR construction (counting sort by source).

use super::csr::{Csr, Graph};
use super::Edge;
use crate::util::sort::exclusive_prefix_sum;
use crate::VertexId;

/// Accumulates edges and finalizes into CSR with optional symmetrization,
/// deduplication and self-loop removal.
#[derive(Default)]
pub struct GraphBuilder {
    edges: Vec<Edge>,
    n: usize,
    weighted: bool,
    symmetrize: bool,
    dedup: bool,
    drop_self_loops: bool,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Force at least `n` vertices (ids beyond the max edge endpoint).
    pub fn with_n(mut self, n: usize) -> Self {
        self.n = n;
        self
    }

    /// Add the reverse of every edge (undirected semantics, used for CC).
    pub fn symmetrize(mut self) -> Self {
        self.symmetrize = true;
        self
    }

    /// Remove parallel edges (keeping the first occurrence's weight).
    pub fn dedup(mut self) -> Self {
        self.dedup = true;
        self
    }

    pub fn drop_self_loops(mut self) -> Self {
        self.drop_self_loops = true;
        self
    }

    /// Record weights (otherwise the CSR is unweighted).
    pub fn weighted(mut self) -> Self {
        self.weighted = true;
        self
    }

    pub fn add(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.edges.push(Edge::new(src, dst));
        self
    }

    pub fn add_weighted(&mut self, src: VertexId, dst: VertexId, w: f32) -> &mut Self {
        self.weighted = true;
        self.edges.push(Edge::weighted(src, dst, w));
        self
    }

    pub fn extend(&mut self, edges: impl IntoIterator<Item = Edge>) -> &mut Self {
        self.edges.extend(edges);
        self
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn build(mut self) -> Graph {
        if self.drop_self_loops {
            self.edges.retain(|e| e.src != e.dst);
        }
        if self.symmetrize {
            let rev: Vec<Edge> = self.edges.iter().map(|e| Edge::weighted(e.dst, e.src, e.weight)).collect();
            self.edges.extend(rev);
        }
        let n = self
            .edges
            .iter()
            .map(|e| e.src.max(e.dst) as usize + 1)
            .max()
            .unwrap_or(0)
            .max(self.n);
        // Counting sort by src.
        let mut offsets = vec![0u64; n + 1];
        for e in &self.edges {
            offsets[e.src as usize] += 1;
        }
        let total = exclusive_prefix_sum(&mut offsets[..n]);
        offsets[n] = total;
        let mut cursor = offsets[..n].to_vec();
        let mut targets = vec![0 as VertexId; self.edges.len()];
        let mut weights = if self.weighted { Some(vec![0f32; self.edges.len()]) } else { None };
        for e in &self.edges {
            let slot = cursor[e.src as usize] as usize;
            targets[slot] = e.dst;
            if let Some(w) = &mut weights {
                w[slot] = e.weight;
            }
            cursor[e.src as usize] += 1;
        }
        // Sort each adjacency list (and optionally dedup).
        let mut final_offsets = vec![0u64; n + 1];
        if self.dedup {
            let mut new_targets = Vec::with_capacity(targets.len());
            let mut new_weights = weights.as_ref().map(|_| Vec::with_capacity(targets.len()));
            for v in 0..n {
                let lo = offsets[v] as usize;
                let hi = offsets[v + 1] as usize;
                let mut adj: Vec<(VertexId, f32)> = (lo..hi)
                    .map(|i| (targets[i], weights.as_ref().map_or(1.0, |w| w[i])))
                    .collect();
                adj.sort_by_key(|&(t, _)| t);
                adj.dedup_by_key(|&mut (t, _)| t);
                final_offsets[v + 1] = final_offsets[v] + adj.len() as u64;
                for (t, w) in adj {
                    new_targets.push(t);
                    if let Some(nw) = &mut new_weights {
                        nw.push(w);
                    }
                }
            }
            return Graph::from_csr(Csr::new(n, final_offsets, new_targets, new_weights));
        }
        for v in 0..n {
            let lo = offsets[v] as usize;
            let hi = offsets[v + 1] as usize;
            if let Some(w) = &mut weights {
                let mut adj: Vec<(VertexId, f32)> = (lo..hi).map(|i| (targets[i], w[i])).collect();
                adj.sort_by_key(|&(t, _)| t);
                for (k, (t, wt)) in adj.into_iter().enumerate() {
                    targets[lo + k] = t;
                    w[lo + k] = wt;
                }
            } else {
                targets[lo..hi].sort_unstable();
            }
        }
        Graph::from_csr(Csr::new(n, offsets, targets, weights))
    }
}

/// Convenience: build an unweighted graph from (src, dst) pairs.
pub fn graph_from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Graph {
    let mut b = GraphBuilder::new().with_n(n);
    for &(s, d) in edges {
        b.add(s, d);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_build_sorted_adjacency() {
        let g = graph_from_edges(4, &[(0, 3), (0, 1), (2, 0), (0, 2)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.out().neighbors(0), &[1, 2, 3]);
        assert_eq!(g.out().neighbors(2), &[0]);
        assert_eq!(g.out().neighbors(3), &[] as &[u32]);
    }

    #[test]
    fn with_n_pads_isolated_vertices() {
        let g = graph_from_edges(10, &[(0, 1)]);
        assert_eq!(g.n(), 10);
        assert_eq!(g.out_degree(9), 0);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let mut b = GraphBuilder::new().symmetrize();
        b.add(0, 1).add(1, 2);
        let g = b.build();
        assert_eq!(g.m(), 4);
        assert_eq!(g.out().neighbors(1), &[0, 2]);
    }

    #[test]
    fn dedup_removes_parallel_edges() {
        let mut b = GraphBuilder::new().dedup();
        b.add(0, 1).add(0, 1).add(0, 2);
        let g = b.build();
        assert_eq!(g.m(), 2);
        assert_eq!(g.out().neighbors(0), &[1, 2]);
    }

    #[test]
    fn drop_self_loops() {
        let mut b = GraphBuilder::new().drop_self_loops();
        b.add(0, 0).add(0, 1).add(1, 1);
        let g = b.build();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn weighted_build_keeps_weights_aligned() {
        let mut b = GraphBuilder::new();
        b.add_weighted(0, 2, 2.5).add_weighted(0, 1, 1.5).add_weighted(1, 0, 0.5);
        let g = b.build();
        assert!(g.is_weighted());
        assert_eq!(g.out().neighbors(0), &[1, 2]);
        assert_eq!(g.out().edge_weights(0).unwrap(), &[1.5, 2.5]);
        assert_eq!(g.out().edge_weights(1).unwrap(), &[0.5]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new().with_n(5).build();
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
    }
}
