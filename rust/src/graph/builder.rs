//! Edge-list → CSR construction (counting sort by source) and streaming
//! CSR deltas.
//!
//! [`GraphBuilder::build`] is the serial reference;
//! [`GraphBuilder::build_with_pool`] runs the same pipeline —
//! per-chunk degree histograms → prefix sum → *stable* scatter →
//! per-vertex adjacency sort — over a [`ThreadPool`], producing a
//! bit-identical CSR (pinned by `tests/preprocess.rs`). Stability is
//! what makes that possible: each edge's slot is `offsets[src] +
//! (its rank among same-src edges in input order)`, which per-chunk
//! histogram prefixes reproduce exactly regardless of thread count.
//!
//! [`GraphDelta`] + [`merge_delta`] are the streaming-update path: a
//! batch of edge inserts/deletes is merged into an existing CSR without
//! replaying the whole counting sort, and the merge defines the
//! *canonical* mutated graph that
//! [`BinLayout::apply_delta`](crate::ppm::BinLayout::apply_delta) must
//! reproduce bit-identically against a from-scratch build.

use super::csr::{Csr, Graph};
use super::Edge;
use crate::exec::{SharedSlice, ThreadPool};
use crate::partition::Partitioner;
use crate::util::div_ceil;
use crate::util::sort::exclusive_prefix_sum;
use crate::{PartId, VertexId};

/// Reborrow an optional pool so it can be threaded through several
/// sequential parallel phases.
fn reborrow<'a>(pool: &'a mut Option<&mut ThreadPool>) -> Option<&'a mut ThreadPool> {
    pool.as_mut().map(|p| &mut **p)
}

/// Run `f(chunk)` for every chunk, on the pool when one with workers is
/// available, inline otherwise.
fn run_chunks<F: Fn(usize) + Sync>(pool: Option<&mut ThreadPool>, n_chunks: usize, f: F) {
    match pool {
        Some(p) if p.n_threads() > 1 => p.for_each_dynamic(n_chunks, 1, |c, _tid| f(c)),
        _ => {
            for c in 0..n_chunks {
                f(c);
            }
        }
    }
}

/// Like [`run_chunks`] but collecting owned per-chunk results in order.
fn map_chunks<T: Send, F: Fn(usize) -> T + Sync>(
    pool: Option<&mut ThreadPool>,
    n_chunks: usize,
    f: F,
) -> Vec<T> {
    match pool {
        Some(p) if p.n_threads() > 1 => p.map_parts(n_chunks, f),
        _ => (0..n_chunks).map(f).collect(),
    }
}

/// Split `[0, n)` into `n_chunks` contiguous ranges (the trailing ones
/// may be empty).
fn chunk_ranges(n: usize, n_chunks: usize) -> Vec<std::ops::Range<usize>> {
    let n_chunks = n_chunks.max(1);
    let per = div_ceil(n, n_chunks).max(1);
    (0..n_chunks).map(|c| (c * per).min(n)..((c + 1) * per).min(n)).collect()
}

/// Accumulates edges and finalizes into CSR with optional symmetrization,
/// deduplication and self-loop removal.
#[derive(Default)]
pub struct GraphBuilder {
    edges: Vec<Edge>,
    n: usize,
    weighted: bool,
    symmetrize: bool,
    dedup: bool,
    drop_self_loops: bool,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Force at least `n` vertices (ids beyond the max edge endpoint).
    pub fn with_n(mut self, n: usize) -> Self {
        self.n = n;
        self
    }

    /// Add the reverse of every edge (undirected semantics, used for CC).
    pub fn symmetrize(mut self) -> Self {
        self.symmetrize = true;
        self
    }

    /// Remove parallel edges (keeping the first occurrence's weight).
    pub fn dedup(mut self) -> Self {
        self.dedup = true;
        self
    }

    pub fn drop_self_loops(mut self) -> Self {
        self.drop_self_loops = true;
        self
    }

    /// Record weights (otherwise the CSR is unweighted).
    pub fn weighted(mut self) -> Self {
        self.weighted = true;
        self
    }

    pub fn add(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.edges.push(Edge::new(src, dst));
        self
    }

    pub fn add_weighted(&mut self, src: VertexId, dst: VertexId, w: f32) -> &mut Self {
        self.weighted = true;
        self.edges.push(Edge::weighted(src, dst, w));
        self
    }

    pub fn extend(&mut self, edges: impl IntoIterator<Item = Edge>) -> &mut Self {
        self.edges.extend(edges);
        self
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Serial build.
    pub fn build(self) -> Graph {
        self.build_impl(None)
    }

    /// Parallel build over `pool` — every `O(E)` / `O(n)` pass (vertex
    /// count, degree histogram, scatter, per-vertex sort, dedup) runs as
    /// pool tasks. Bit-identical to [`build`] for any thread count.
    pub fn build_with_pool(self, pool: &mut ThreadPool) -> Graph {
        self.build_impl(Some(pool))
    }

    fn build_impl(mut self, mut pool: Option<&mut ThreadPool>) -> Graph {
        if self.drop_self_loops {
            self.edges.retain(|e| e.src != e.dst);
        }
        if self.symmetrize {
            let rev: Vec<Edge> =
                self.edges.iter().map(|e| Edge::weighted(e.dst, e.src, e.weight)).collect();
            self.edges.extend(rev);
        }
        let edges = std::mem::take(&mut self.edges);
        let m = edges.len();
        let n_chunks = match pool.as_ref() {
            Some(p) if p.n_threads() > 1 => p.n_threads().min(m.max(1)),
            _ => 1,
        };
        let e_ranges = chunk_ranges(m, n_chunks);

        let n = map_chunks(reborrow(&mut pool), n_chunks, |c| {
            edges[e_ranges[c].clone()]
                .iter()
                .map(|e| e.src.max(e.dst) as usize + 1)
                .max()
                .unwrap_or(0)
        })
        .into_iter()
        .max()
        .unwrap_or(0)
        .max(self.n);

        // Counting sort by src, phase 1: per-chunk degree histograms.
        let mut hists: Vec<Vec<u32>> = map_chunks(reborrow(&mut pool), n_chunks, |c| {
            let mut h = vec![0u32; n];
            for e in &edges[e_ranges[c].clone()] {
                h[e.src as usize] += 1;
            }
            h
        });

        // Phase 2 (serial, O(n_chunks * n)): turn each chunk's count
        // into its stable start rank (edges of `v` in earlier chunks),
        // and accumulate global offsets.
        let mut offsets = vec![0u64; n + 1];
        for v in 0..n {
            let mut run = 0u64;
            for h in hists.iter_mut() {
                let cnt = h[v] as u64;
                // Hard assert: the disjoint-slot safety of the unsafe
                // scatter below relies on these ranks not wrapping.
                assert!(run <= u32::MAX as u64, "per-vertex degree exceeds u32");
                h[v] = run as u32;
                run += cnt;
            }
            offsets[v] = run;
        }
        let total = exclusive_prefix_sum(&mut offsets[..n]);
        offsets[n] = total;
        debug_assert_eq!(total as usize, m);

        // Phase 3: stable parallel scatter — chunk `c` places its edges
        // at offsets[src] + (rank before chunk c) + (rank within chunk),
        // exactly the slot the serial input-order scatter assigns.
        let mut targets = vec![0 as VertexId; m];
        let mut weights = if self.weighted { Some(vec![0f32; m]) } else { None };
        {
            let t_slots = SharedSlice::new(&mut targets);
            let w_slots = weights.as_mut().map(|w| SharedSlice::new(&mut w[..]));
            let cursors = SharedSlice::new(&mut hists);
            run_chunks(reborrow(&mut pool), n_chunks, |c| {
                // SAFETY: chunk c exclusively owns hists[c]; edge slots
                // are globally unique by the stable-rank construction.
                let cur = unsafe { cursors.get_mut(c) };
                for e in &edges[e_ranges[c].clone()] {
                    let v = e.src as usize;
                    let slot = (offsets[v] + cur[v] as u64) as usize;
                    cur[v] += 1;
                    // SAFETY: `slot` is globally unique (stable-rank
                    // construction), so no two chunks write it.
                    unsafe {
                        t_slots.write(slot, e.dst);
                        if let Some(w) = &w_slots {
                            w.write(slot, e.weight);
                        }
                    }
                }
            });
        }
        drop(hists);
        drop(edges);

        // Per-vertex adjacency passes are chunked over vertices.
        let v_ranges = chunk_ranges(n, n_chunks * 4);

        if self.dedup {
            // Each vertex chunk independently sorts + dedups its
            // adjacency lists into an owned block, then blocks are
            // concatenated in order (deterministic, == serial).
            let blocks: Vec<(Vec<VertexId>, Vec<f32>, Vec<u32>)> =
                map_chunks(reborrow(&mut pool), v_ranges.len(), |c| {
                    let mut ts = Vec::new();
                    let mut ws = Vec::new();
                    let mut lens = Vec::with_capacity(v_ranges[c].len());
                    for v in v_ranges[c].clone() {
                        let lo = offsets[v] as usize;
                        let hi = offsets[v + 1] as usize;
                        let mut adj: Vec<(VertexId, f32)> = (lo..hi)
                            .map(|i| (targets[i], weights.as_ref().map_or(1.0, |w| w[i])))
                            .collect();
                        adj.sort_by_key(|&(t, _)| t);
                        adj.dedup_by_key(|&mut (t, _)| t);
                        lens.push(adj.len() as u32);
                        for (t, w) in adj {
                            ts.push(t);
                            if self.weighted {
                                ws.push(w);
                            }
                        }
                    }
                    (ts, ws, lens)
                });
            let mut final_offsets = vec![0u64; n + 1];
            let mut new_targets = Vec::with_capacity(m);
            let mut new_weights = self.weighted.then(|| Vec::with_capacity(m));
            let mut v = 0usize;
            for (ts, ws, lens) in blocks {
                for len in lens {
                    final_offsets[v + 1] = final_offsets[v] + len as u64;
                    v += 1;
                }
                new_targets.extend_from_slice(&ts);
                if let Some(nw) = &mut new_weights {
                    nw.extend_from_slice(&ws);
                }
            }
            debug_assert_eq!(v, n);
            return Graph::from_csr(Csr::new(n, final_offsets, new_targets, new_weights));
        }

        // Sort each adjacency list in place (disjoint slices per vertex).
        {
            let t_slots = SharedSlice::new(&mut targets);
            let w_slots = weights.as_mut().map(|w| SharedSlice::new(&mut w[..]));
            run_chunks(reborrow(&mut pool), v_ranges.len(), |c| {
                for v in v_ranges[c].clone() {
                    let lo = offsets[v] as usize;
                    let hi = offsets[v + 1] as usize;
                    if hi - lo <= 1 {
                        continue;
                    }
                    // SAFETY: vertex ranges are disjoint across chunks,
                    // and [lo, hi) slices are disjoint across vertices.
                    unsafe {
                        match &w_slots {
                            Some(w) => {
                                let tv = t_slots.slice_mut(lo, hi);
                                let wv = w.slice_mut(lo, hi);
                                let mut adj: Vec<(VertexId, f32)> =
                                    tv.iter().copied().zip(wv.iter().copied()).collect();
                                adj.sort_by_key(|&(t, _)| t);
                                for (i, (t, wt)) in adj.into_iter().enumerate() {
                                    tv[i] = t;
                                    wv[i] = wt;
                                }
                            }
                            None => t_slots.slice_mut(lo, hi).sort_unstable(),
                        }
                    }
                }
            });
        }
        Graph::from_csr(Csr::new(n, offsets, targets, weights))
    }
}

/// Relabel `graph` through a vertex permutation (`forward[old] = new`,
/// `inverse[new] = old` — see [`crate::reorder::Permutation`]): new
/// vertex `nv` takes the adjacency of `inverse[nv]` with every target
/// mapped through `forward`, each row re-sorted by new target id so the
/// result satisfies the same sorted-adjacency invariant the builder
/// produces.
///
/// Runs the scatter + per-row sort over `pool` when one with workers is
/// given. Bit-identical to the serial pass at any thread count: every
/// output row is a pure function of `(graph, forward, inverse)` and
/// rows are disjoint, so the chunking changes nothing but wall-clock
/// (pinned by `permute_parallel_bit_identical_to_serial`). Weighted
/// rows sort stably by target, so parallel edges keep the relative
/// weight order of the source row.
pub fn permute_graph(
    graph: &Graph,
    forward: &[VertexId],
    inverse: &[VertexId],
    mut pool: Option<&mut ThreadPool>,
) -> Graph {
    let n = graph.n();
    assert_eq!(forward.len(), n, "forward mapping must cover every vertex");
    assert_eq!(inverse.len(), n, "inverse mapping must cover every vertex");
    let csr = graph.out();
    let m = csr.m();
    let mut offsets = vec![0u64; n + 1];
    for nv in 0..n {
        offsets[nv] = csr.degree(inverse[nv]) as u64;
    }
    let total = exclusive_prefix_sum(&mut offsets[..n]);
    offsets[n] = total;
    debug_assert_eq!(total as usize, m);

    let n_chunks = match pool.as_ref() {
        Some(p) if p.n_threads() > 1 => p.n_threads() * 4,
        _ => 1,
    };
    let v_ranges = chunk_ranges(n, n_chunks);
    let mut targets = vec![0 as VertexId; m];
    let mut weights = csr.is_weighted().then(|| vec![0f32; m]);
    {
        let t_slots = SharedSlice::new(&mut targets);
        let w_slots = weights.as_mut().map(|w| SharedSlice::new(&mut w[..]));
        run_chunks(reborrow(&mut pool), v_ranges.len(), |c| {
            for nv in v_ranges[c].clone() {
                let old = inverse[nv];
                let lo = offsets[nv] as usize;
                let hi = offsets[nv + 1] as usize;
                let adj = csr.neighbors(old);
                // SAFETY: vertex ranges are disjoint across chunks, and
                // [lo, hi) output slices are disjoint across vertices
                // (exclusive prefix sum over per-vertex degrees).
                unsafe {
                    match (&w_slots, csr.edge_weights(old)) {
                        (Some(w), Some(win)) => {
                            let tv = t_slots.slice_mut(lo, hi);
                            let wv = w.slice_mut(lo, hi);
                            let mut pairs: Vec<(VertexId, f32)> = adj
                                .iter()
                                .map(|&u| forward[u as usize])
                                .zip(win.iter().copied())
                                .collect();
                            pairs.sort_by_key(|&(t, _)| t);
                            for (i, (t, wt)) in pairs.into_iter().enumerate() {
                                tv[i] = t;
                                wv[i] = wt;
                            }
                        }
                        _ => {
                            let tv = t_slots.slice_mut(lo, hi);
                            for (i, &u) in adj.iter().enumerate() {
                                tv[i] = forward[u as usize];
                            }
                            tv.sort_unstable();
                        }
                    }
                }
            }
        });
    }
    Graph::from_csr(Csr::new(n, offsets, targets, weights))
}

/// Convenience: build an unweighted graph from (src, dst) pairs.
pub fn graph_from_edges(n: usize, edges: &[(VertexId, VertexId)]) -> Graph {
    let mut b = GraphBuilder::new().with_n(n);
    for &(s, d) in edges {
        b.add(s, d);
    }
    b.build()
}

/// A batch of streaming edge updates against an existing graph.
///
/// Batch semantics (what [`merge_delta`] implements):
///
/// - Endpoints must name *existing* vertices (`< n`): deltas never grow
///   the vertex set — that changes the partitioning and needs a full
///   [`swap_graph`](crate::api::EngineSession::swap_graph).
/// - A delete removes **every** parallel `src -> dst` edge; deleting an
///   absent edge is a no-op (streams may replay safely).
/// - Deletes apply to the pre-delta adjacency first, then inserts are
///   added — an edge both deleted and inserted in one batch ends up
///   present, carrying the inserted weight.
/// - Weight handling follows the graph: inserts into a weighted graph
///   carry their [`Edge::weight`]; into an unweighted graph the weight
///   is ignored.
#[derive(Clone, Debug, Default)]
pub struct GraphDelta {
    inserts: Vec<Edge>,
    deletes: Vec<(VertexId, VertexId)>,
}

impl GraphDelta {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue an unweighted edge insert.
    pub fn insert(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.inserts.push(Edge::new(src, dst));
        self
    }

    /// Queue a weighted edge insert (the weight is ignored when the
    /// delta is merged into an unweighted graph).
    pub fn insert_weighted(&mut self, src: VertexId, dst: VertexId, w: f32) -> &mut Self {
        self.inserts.push(Edge::weighted(src, dst, w));
        self
    }

    /// Queue a delete of every parallel `src -> dst` edge (a no-op if
    /// none exist).
    pub fn delete(&mut self, src: VertexId, dst: VertexId) -> &mut Self {
        self.deletes.push((src, dst));
        self
    }

    pub fn inserts(&self) -> &[Edge] {
        &self.inserts
    }

    pub fn deletes(&self) -> &[(VertexId, VertexId)] {
        &self.deletes
    }

    pub fn is_empty(&self) -> bool {
        self.inserts.is_empty() && self.deletes.is_empty()
    }

    /// Queued updates (inserts + deletes).
    pub fn len(&self) -> usize {
        self.inserts.len() + self.deletes.len()
    }

    /// Source partitions whose bin rows this delta invalidates, sorted
    /// and deduplicated — the rows
    /// [`BinLayout::apply_delta`](crate::ppm::BinLayout::apply_delta)
    /// recomputes. A bin row depends only on the out-edges of its own
    /// partition's vertices, so `part_of(src)` for every insert and
    /// delete is exactly the invalidation set. Endpoints must already be
    /// validated against the graph (see [`merge_delta`]).
    pub fn dirty_parts(&self, parts: &Partitioner) -> Vec<PartId> {
        let mut dirty: Vec<PartId> = self
            .inserts
            .iter()
            .map(|e| parts.part_of(e.src))
            .chain(self.deletes.iter().map(|&(s, _)| parts.part_of(s)))
            .collect();
        dirty.sort_unstable();
        dirty.dedup();
        dirty
    }
}

/// Apply `delta` to `graph`, producing the canonical mutated CSR (the
/// graph [`BinLayout::apply_delta`](crate::ppm::BinLayout::apply_delta)
/// is bit-identical to a from-scratch build over). See [`GraphDelta`]
/// for the batch semantics.
///
/// Untouched vertices keep their adjacency byte-for-byte (including any
/// unsorted order a `read_binary` file may carry); a touched vertex's
/// surviving + inserted edges are stably re-sorted by target, so
/// existing edges keep their relative order and inserted edges follow
/// them (in batch order) among equal targets.
///
/// `O(E + |delta| log |delta|)` — one sequential pass over the CSR; the
/// savings of the delta path are on the layout side, where only dirty
/// partition rows are re-scanned.
pub fn merge_delta(graph: &Graph, delta: &GraphDelta) -> Result<Graph, String> {
    let n = graph.n();
    for e in delta.inserts() {
        if e.src as usize >= n || e.dst as usize >= n {
            return Err(format!(
                "delta insert {}->{} names a vertex outside the graph (n = {n}); growing \
                 the vertex set needs a full graph swap, not a delta",
                e.src, e.dst
            ));
        }
    }
    for &(s, d) in delta.deletes() {
        if s as usize >= n || d as usize >= n {
            return Err(format!(
                "delta delete {s}->{d} names a vertex outside the graph (n = {n})"
            ));
        }
    }
    let csr = graph.out();
    let weighted = graph.is_weighted();
    // Group inserts by source; the sort is stable, so each vertex's
    // inserts stay in batch order.
    let mut ins: Vec<Edge> = delta.inserts().to_vec();
    ins.sort_by_key(|e| e.src);
    let del: std::collections::HashSet<(VertexId, VertexId)> =
        delta.deletes().iter().copied().collect();
    // Gate the per-edge delete probes on a per-vertex membership test, so
    // a small delta costs O(n) source checks + probes on actual delete
    // sources — not O(E) hash lookups across the whole copy-through.
    let del_srcs: std::collections::HashSet<VertexId> =
        delta.deletes().iter().map(|&(s, _)| s).collect();

    let mut offsets = vec![0u64; n + 1];
    let mut targets: Vec<VertexId> = Vec::with_capacity(csr.m() + ins.len());
    let mut weights: Option<Vec<f32>> =
        if weighted { Some(Vec::with_capacity(csr.m() + ins.len())) } else { None };
    let mut ins_cursor = 0usize;
    for v in 0..n as VertexId {
        let adj = csr.neighbors(v);
        let wts = csr.edge_weights(v);
        let ins_lo = ins_cursor;
        while ins_cursor < ins.len() && ins[ins_cursor].src == v {
            ins_cursor += 1;
        }
        let v_ins = &ins[ins_lo..ins_cursor];
        let touched = !v_ins.is_empty()
            || (del_srcs.contains(&v) && adj.iter().any(|&u| del.contains(&(v, u))));
        if touched {
            let mut merged: Vec<(VertexId, f32)> = Vec::with_capacity(adj.len() + v_ins.len());
            for (i, &u) in adj.iter().enumerate() {
                if !del.contains(&(v, u)) {
                    merged.push((u, wts.map_or(1.0, |ws| ws[i])));
                }
            }
            for e in v_ins {
                merged.push((e.dst, e.weight));
            }
            merged.sort_by_key(|&(u, _)| u);
            for (u, w) in merged {
                targets.push(u);
                if let Some(ws) = &mut weights {
                    ws.push(w);
                }
            }
        } else {
            targets.extend_from_slice(adj);
            if let (Some(ws), Some(vw)) = (&mut weights, wts) {
                ws.extend_from_slice(vw);
            }
        }
        offsets[v as usize + 1] = targets.len() as u64;
    }
    Ok(Graph::from_csr(Csr::new(n, offsets, targets, weights)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_build_sorted_adjacency() {
        let g = graph_from_edges(4, &[(0, 3), (0, 1), (2, 0), (0, 2)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.out().neighbors(0), &[1, 2, 3]);
        assert_eq!(g.out().neighbors(2), &[0]);
        assert_eq!(g.out().neighbors(3), &[] as &[u32]);
    }

    #[test]
    fn with_n_pads_isolated_vertices() {
        let g = graph_from_edges(10, &[(0, 1)]);
        assert_eq!(g.n(), 10);
        assert_eq!(g.out_degree(9), 0);
    }

    #[test]
    fn symmetrize_doubles_edges() {
        let mut b = GraphBuilder::new().symmetrize();
        b.add(0, 1).add(1, 2);
        let g = b.build();
        assert_eq!(g.m(), 4);
        assert_eq!(g.out().neighbors(1), &[0, 2]);
    }

    #[test]
    fn dedup_removes_parallel_edges() {
        let mut b = GraphBuilder::new().dedup();
        b.add(0, 1).add(0, 1).add(0, 2);
        let g = b.build();
        assert_eq!(g.m(), 2);
        assert_eq!(g.out().neighbors(0), &[1, 2]);
    }

    #[test]
    fn drop_self_loops() {
        let mut b = GraphBuilder::new().drop_self_loops();
        b.add(0, 0).add(0, 1).add(1, 1);
        let g = b.build();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn weighted_build_keeps_weights_aligned() {
        let mut b = GraphBuilder::new();
        b.add_weighted(0, 2, 2.5).add_weighted(0, 1, 1.5).add_weighted(1, 0, 0.5);
        let g = b.build();
        assert!(g.is_weighted());
        assert_eq!(g.out().neighbors(0), &[1, 2]);
        assert_eq!(g.out().edge_weights(0).unwrap(), &[1.5, 2.5]);
        assert_eq!(g.out().edge_weights(1).unwrap(), &[0.5]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new().with_n(5).build();
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
    }

    fn random_edges(seed: u64, n: usize, m: usize) -> Vec<Edge> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..m)
            .map(|_| {
                Edge::weighted(
                    rng.below(n as u64) as VertexId,
                    rng.below(n as u64) as VertexId,
                    rng.next_f32(),
                )
            })
            .collect()
    }

    fn assert_same_graph(a: &Graph, b: &Graph, ctx: &str) {
        assert_eq!(a.n(), b.n(), "{ctx}: n");
        assert_eq!(a.out().offsets(), b.out().offsets(), "{ctx}: offsets");
        assert_eq!(a.out().targets(), b.out().targets(), "{ctx}: targets");
        let (wa, wb) = (a.out().weights(), b.out().weights());
        assert_eq!(wa.map(|w| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
                   wb.map(|w| w.iter().map(|x| x.to_bits()).collect::<Vec<_>>()),
                   "{ctx}: weights");
    }

    #[test]
    fn parallel_build_bit_identical_to_serial() {
        for t in [1usize, 2, 4] {
            for (weighted, dedup, sym) in [
                (false, false, false),
                (true, false, false),
                (true, true, false),
                (false, true, true),
            ] {
                let edges = random_edges(0xBEEF + t as u64, 97, 900);
                let make = || {
                    let mut b = GraphBuilder::new().with_n(120);
                    if weighted {
                        b = b.weighted();
                    }
                    if dedup {
                        b = b.dedup();
                    }
                    if sym {
                        b = b.symmetrize().drop_self_loops();
                    }
                    b.extend(edges.iter().copied());
                    b
                };
                let serial = make().build();
                let mut pool = ThreadPool::new(t);
                let par = make().build_with_pool(&mut pool);
                assert_same_graph(
                    &serial,
                    &par,
                    &format!("t={t} weighted={weighted} dedup={dedup} sym={sym}"),
                );
            }
        }
    }

    #[test]
    fn merge_delta_inserts_sorted_and_deletes_all_parallel() {
        // 0 -> {1, 2, 2}, 1 -> {0}
        let mut b = GraphBuilder::new().with_n(4);
        b.add(0, 1).add(0, 2).add(0, 2).add(1, 0);
        let g = b.build();
        let mut d = GraphDelta::new();
        d.insert(0, 3).insert(2, 0).delete(0, 2).delete(3, 1); // 3->1 absent: no-op
        let m = merge_delta(&g, &d).unwrap();
        assert_eq!(m.out().neighbors(0), &[1, 3], "both parallel 0->2 edges removed");
        assert_eq!(m.out().neighbors(1), &[0]);
        assert_eq!(m.out().neighbors(2), &[0]);
        assert_eq!(m.m(), 4);
    }

    #[test]
    fn merge_delta_empty_is_identity() {
        let g = graph_from_edges(5, &[(0, 1), (2, 4), (4, 0)]);
        let m = merge_delta(&g, &GraphDelta::new()).unwrap();
        assert_eq!(m, g);
    }

    #[test]
    fn merge_delta_delete_then_insert_same_edge_keeps_it() {
        let mut b = GraphBuilder::new();
        b.add_weighted(0, 1, 2.0).add_weighted(0, 2, 3.0);
        let g = b.build();
        let mut d = GraphDelta::new();
        d.delete(0, 1);
        d.insert_weighted(0, 1, 9.0);
        let m = merge_delta(&g, &d).unwrap();
        assert_eq!(m.out().neighbors(0), &[1, 2]);
        assert_eq!(m.out().edge_weights(0).unwrap(), &[9.0, 3.0], "inserted weight wins");
    }

    #[test]
    fn merge_delta_weighted_keeps_existing_before_inserted() {
        // Equal targets: the surviving existing edge precedes the insert.
        let mut b = GraphBuilder::new();
        b.add_weighted(0, 1, 1.0);
        let g = b.build();
        let mut d = GraphDelta::new();
        d.insert_weighted(0, 1, 7.0);
        let m = merge_delta(&g, &d).unwrap();
        assert_eq!(m.out().neighbors(0), &[1, 1]);
        assert_eq!(m.out().edge_weights(0).unwrap(), &[1.0, 7.0]);
    }

    #[test]
    fn merge_delta_insert_weight_ignored_on_unweighted_graph() {
        let g = graph_from_edges(3, &[(0, 1)]);
        let mut d = GraphDelta::new();
        d.insert_weighted(1, 2, 5.0);
        let m = merge_delta(&g, &d).unwrap();
        assert!(!m.is_weighted());
        assert_eq!(m.out().neighbors(1), &[2]);
    }

    #[test]
    fn merge_delta_rejects_out_of_range_endpoints() {
        let g = graph_from_edges(3, &[(0, 1)]);
        let mut grow = GraphDelta::new();
        grow.insert(0, 3);
        assert!(merge_delta(&g, &grow).unwrap_err().contains("graph swap"));
        let mut bad_del = GraphDelta::new();
        bad_del.delete(9, 0);
        assert!(merge_delta(&g, &bad_del).is_err());
    }

    #[test]
    fn dirty_parts_sorted_dedup_sources_only() {
        let parts = Partitioner::with_k(100, 10); // q = 10
        let mut d = GraphDelta::new();
        d.insert(55, 3).insert(51, 99).delete(12, 80).delete(58, 0);
        assert_eq!(d.dirty_parts(&parts), vec![1, 5], "only source partitions are dirty");
        assert_eq!(d.len(), 4);
        assert!(!d.is_empty());
    }

    #[test]
    fn permute_parallel_bit_identical_to_serial() {
        for weighted in [false, true] {
            let mut b = GraphBuilder::new().with_n(130);
            if weighted {
                b = b.weighted();
            }
            b.extend(random_edges(0xF00D, 113, 1200));
            let g = b.build();
            // An arbitrary deterministic permutation: reverse ids.
            let n = g.n();
            let forward: Vec<VertexId> = (0..n as VertexId).map(|v| n as u32 - 1 - v).collect();
            let inverse = forward.clone();
            let serial = permute_graph(&g, &forward, &inverse, None);
            assert_eq!(serial.m(), g.m());
            for t in [2usize, 4] {
                let mut pool = ThreadPool::new(t);
                let par = permute_graph(&g, &forward, &inverse, Some(&mut pool));
                assert_same_graph(&serial, &par, &format!("permute t={t} weighted={weighted}"));
            }
            // Row contents survive the relabeling.
            for v in 0..n as VertexId {
                let mut expect: Vec<VertexId> =
                    g.out().neighbors(v).iter().map(|&u| forward[u as usize]).collect();
                expect.sort_unstable();
                assert_eq!(serial.out().neighbors(forward[v as usize]), &expect[..]);
            }
        }
    }

    #[test]
    fn parallel_build_empty_and_tiny() {
        let mut pool = ThreadPool::new(4);
        let g = GraphBuilder::new().with_n(5).build_with_pool(&mut pool);
        assert_eq!(g.n(), 5);
        assert_eq!(g.m(), 0);
        let mut b = GraphBuilder::new();
        b.add(0, 1);
        let g = b.build_with_pool(&mut pool);
        assert_eq!(g.m(), 1);
        assert_eq!(g.out().neighbors(0), &[1]);
    }
}
