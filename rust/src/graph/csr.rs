//! Compressed Sparse Row / Column storage (paper §2).
//!
//! `Csr` packs out-edges sorted by source with a metadata offsets array;
//! the same structure indexed by destination serves as CSC. [`Graph`]
//! couples the two views: GPOP's scatter and the push baselines walk the
//! CSR; the pull/SpMV baselines and the PNG construction walk the CSC.

use crate::{VertexId, Weight};

/// Adjacency in compressed sparse row form.
#[derive(Clone, Debug)]
pub struct Csr {
    n: usize,
    offsets: Vec<u64>, // n + 1 entries
    targets: Vec<VertexId>,
    weights: Option<Vec<Weight>>,
}

/// Equality is *bitwise*: weights compare by their bit patterns, not
/// `f32` equality, so `write_binary → read_binary` roundtrips and
/// layout-persistence checks stay exact even for files that carry NaN
/// weights (both IO readers accept them).
impl PartialEq for Csr {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.offsets == other.offsets
            && self.targets == other.targets
            && match (&self.weights, &other.weights) {
                (None, None) => true,
                (Some(a), Some(b)) => {
                    a.len() == b.len()
                        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                }
                _ => false,
            }
    }
}

impl Csr {
    pub fn new(n: usize, offsets: Vec<u64>, targets: Vec<VertexId>, weights: Option<Vec<Weight>>) -> Self {
        assert_eq!(offsets.len(), n + 1, "offsets must have n+1 entries");
        assert_eq!(offsets[0], 0);
        assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        if let Some(w) = &weights {
            assert_eq!(w.len(), targets.len());
        }
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets must be monotone");
        debug_assert!(targets.iter().all(|&t| (t as usize) < n), "target out of range");
        Self { n, offsets, targets, weights }
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Offsets-only skeleton for the out-of-core path
    /// ([`crate::ooc::PartitionStore`]): degrees and edge bases resolve,
    /// adjacency does not — it pages in through the partition cache.
    /// `weights` presence is tracked (empty) so [`Self::is_weighted`]
    /// answers correctly.
    pub(crate) fn skeleton(n: usize, offsets: Vec<u64>, weighted: bool) -> Self {
        assert_eq!(offsets.len(), n + 1, "offsets must have n+1 entries");
        assert_eq!(offsets[0], 0);
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets must be monotone");
        Self { n, offsets, targets: Vec::new(), weights: weighted.then(Vec::new) }
    }

    /// Whether this CSR carries only offsets (an out-of-core skeleton):
    /// its edge count comes from the offsets, not a resident adjacency
    /// array.
    pub(crate) fn is_skeleton(&self) -> bool {
        self.targets.len() as u64 != *self.offsets.last().unwrap()
    }

    /// Number of edges. Derived from the offsets so skeletons (which
    /// hold no targets) report the true count; identical to
    /// `targets.len()` for fully resident CSRs (asserted in
    /// [`Self::new`]).
    #[inline]
    pub fn m(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Neighbors of `v` (out-neighbors for CSR, in-neighbors for CSC).
    #[inline]
    pub fn neighbors(&self, v: VertexId) -> &[VertexId] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// Edge weights parallel to [`Self::neighbors`]; `None` if unweighted.
    #[inline]
    pub fn edge_weights(&self, v: VertexId) -> Option<&[Weight]> {
        self.weights.as_ref().map(|w| {
            let lo = self.offsets[v as usize] as usize;
            let hi = self.offsets[v as usize + 1] as usize;
            &w[lo..hi]
        })
    }

    #[inline]
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    #[inline]
    pub fn targets(&self) -> &[VertexId] {
        &self.targets
    }

    #[inline]
    pub fn weights(&self) -> Option<&[Weight]> {
        self.weights.as_deref()
    }

    pub fn is_weighted(&self) -> bool {
        self.weights.is_some()
    }

    /// Build the transposed view (CSC from CSR or vice versa).
    pub fn transpose(&self) -> Csr {
        assert!(
            !self.is_skeleton(),
            "cannot transpose an out-of-core skeleton CSR: its adjacency is not resident \
             (pull-based apps need the in-memory path)"
        );
        let n = self.n;
        let mut counts = vec![0u64; n + 1];
        for &t in &self.targets {
            counts[t as usize] += 1;
        }
        let mut offsets = vec![0u64; n + 1];
        let mut acc = 0u64;
        for v in 0..n {
            offsets[v] = acc;
            acc += counts[v];
        }
        offsets[n] = acc;
        let mut cursor = offsets.clone();
        let mut targets = vec![0 as VertexId; self.m()];
        let mut weights = self.weights.as_ref().map(|_| vec![0.0 as Weight; self.m()]);
        for u in 0..n as VertexId {
            let lo = self.offsets[u as usize] as usize;
            for (k, &v) in self.neighbors(u).iter().enumerate() {
                let slot = cursor[v as usize] as usize;
                targets[slot] = u;
                if let (Some(wout), Some(win)) = (&mut weights, &self.weights) {
                    wout[slot] = win[lo + k];
                }
                cursor[v as usize] += 1;
            }
        }
        Csr::new(n, offsets, targets, weights)
    }
}

/// A graph with its out-edge (CSR) view and a lazily-computed in-edge
/// (CSC) view.
#[derive(Clone, Debug)]
pub struct Graph {
    csr: Csr,
    csc: Option<Csr>,
}

/// Equality compares the CSR (structure + weights) only: the lazily
/// materialized CSC is a derived cache, not part of the graph's
/// identity, so a graph that has computed its CSC still equals one that
/// has not.
impl PartialEq for Graph {
    fn eq(&self, other: &Self) -> bool {
        self.csr == other.csr
    }
}

impl Graph {
    pub fn from_csr(csr: Csr) -> Self {
        Self { csr, csc: None }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.csr.n()
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.csr.m()
    }

    #[inline]
    pub fn out(&self) -> &Csr {
        &self.csr
    }

    /// In-edge view; computed on first use.
    pub fn ensure_csc(&mut self) -> &Csr {
        if self.csc.is_none() {
            self.csc = Some(self.csr.transpose());
        }
        self.csc.as_ref().unwrap()
    }

    /// In-edge view if already materialized.
    pub fn csc(&self) -> Option<&Csr> {
        self.csc.as_ref()
    }

    #[inline]
    pub fn out_degree(&self, v: VertexId) -> usize {
        self.csr.degree(v)
    }

    pub fn is_weighted(&self) -> bool {
        self.csr.is_weighted()
    }

    /// Total bytes of the CSR arrays (offsets + targets + weights); used
    /// by the DRAM-traffic model and reports.
    pub fn csr_bytes(&self) -> usize {
        self.csr.offsets.len() * 8
            + self.csr.targets.len() * 4
            + self.csr.weights.as_ref().map_or(0, |w| w.len() * 4)
    }

    /// Degree distribution summary: (max, mean, count of zero-degree).
    pub fn degree_stats(&self) -> (usize, f64, usize) {
        let n = self.n().max(1);
        let mut max = 0usize;
        let mut zeros = 0usize;
        for v in 0..self.n() as VertexId {
            let d = self.out_degree(v);
            max = max.max(d);
            if d == 0 {
                zeros += 1;
            }
        }
        (max, self.m() as f64 / n as f64, zeros)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 -> 1, 0 -> 2, 1 -> 2, 2 -> 0
    fn diamond() -> Csr {
        Csr::new(3, vec![0, 2, 3, 4], vec![1, 2, 2, 0], None)
    }

    #[test]
    fn basic_accessors() {
        let g = diamond();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 4);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(2), &[0]);
        assert!(!g.is_weighted());
    }

    #[test]
    fn transpose_roundtrip() {
        let g = diamond();
        let t = g.transpose();
        assert_eq!(t.m(), 4);
        assert_eq!(t.neighbors(2), &[0, 1]); // in-neighbors of 2
        assert_eq!(t.neighbors(0), &[2]);
        let back = t.transpose();
        assert_eq!(back.offsets(), g.offsets());
        assert_eq!(back.targets(), g.targets());
    }

    #[test]
    fn transpose_preserves_weights() {
        let g = Csr::new(3, vec![0, 2, 3, 4], vec![1, 2, 2, 0], Some(vec![0.5, 1.5, 2.5, 3.5]));
        let t = g.transpose();
        // in-edges of 2 are (0->2, w=1.5) and (1->2, w=2.5)
        assert_eq!(t.neighbors(2), &[0, 1]);
        assert_eq!(t.edge_weights(2).unwrap(), &[1.5, 2.5]);
    }

    #[test]
    fn graph_csc_lazy() {
        let mut g = Graph::from_csr(diamond());
        assert!(g.csc().is_none());
        let csc = g.ensure_csc();
        assert_eq!(csc.neighbors(2), &[0, 1]);
        assert!(g.csc().is_some());
    }

    #[test]
    fn degree_stats() {
        let g = Graph::from_csr(Csr::new(4, vec![0, 2, 3, 4, 4], vec![1, 2, 2, 0], None));
        let (max, mean, zeros) = g.degree_stats();
        assert_eq!(max, 2);
        assert!((mean - 1.0).abs() < 1e-12);
        assert_eq!(zeros, 1);
    }

    #[test]
    #[should_panic]
    fn bad_offsets_rejected() {
        let _ = Csr::new(2, vec![0, 1], vec![0], None); // needs 3 offsets
    }

    #[test]
    fn skeleton_reports_degrees_without_adjacency() {
        let s = Csr::skeleton(3, vec![0, 2, 3, 4], true);
        assert!(s.is_skeleton());
        assert_eq!(s.m(), 4);
        assert_eq!(s.degree(0), 2);
        assert_eq!(s.degree(2), 1);
        assert!(s.is_weighted());
        assert!(!diamond().is_skeleton());
    }

    #[test]
    #[should_panic(expected = "skeleton")]
    fn skeleton_transpose_rejected() {
        let _ = Csr::skeleton(3, vec![0, 2, 3, 4], false).transpose();
    }
}
