//! Tests for the typed multi-lane message plane (the PR 2 redesign):
//!
//! 1. **Payload round-trip properties** — every provided 1- and 2-lane
//!    [`Payload`] impl survives encode → decode on random bit patterns.
//! 2. **Weighted `apply_weight` parity per mode** — SC-only, DC-only
//!    and hybrid scatter produce *bit-identical* SSSP distances on a
//!    weighted RMAT graph, and all agree with serial Dijkstra (the
//!    previously untested per-mode weighted path).
//! 3. **Two-lane algorithms end-to-end** — one-pass SSSP-with-parents
//!    validates against `serial::sssp_dijkstra_parents` (distances
//!    equal, parents form real edges with `dist[v] = dist[parent] + w`)
//!    and k-core against serial peeling, through sessions whose pooled
//!    engines are shared between 1- and 2-lane programs.

#[path = "prop_framework/mod.rs"]
mod prop_framework;

use std::sync::Arc;

use gpop::api::{EngineSession, Payload, Runner};
use gpop::apps::{
    sssp_parents::{validate_tree, NO_PARENT},
    Bfs, KCore, Sssp, SsspParents,
};
use gpop::baselines::serial;
use gpop::graph::{gen, Graph};
use gpop::ppm::{ModePolicy, PpmConfig};
use prop_framework::property;

// ---------------------------------------------------------------
// 1. Payload round-trips on random bit patterns
// ---------------------------------------------------------------

fn roundtrip_bits<M: Payload>(bits: u64) -> Result<(), String> {
    let masked = if M::LANES == 1 { bits & 0xFFFF_FFFF } else { bits };
    let decoded = M::from_bits64(masked);
    let re = decoded.to_bits64();
    prop_assert!(
        re == masked,
        "{}-lane payload: {masked:#x} -> {re:#x}",
        M::LANES
    );
    Ok(())
}

#[test]
fn prop_integer_payloads_roundtrip_all_bit_patterns() {
    property("integer payload roundtrip", 200, |g| {
        let bits = g.rng.next_u64();
        roundtrip_bits::<u32>(bits)?;
        roundtrip_bits::<i32>(bits)?;
        roundtrip_bits::<u64>(bits)?;
        roundtrip_bits::<i64>(bits)?;
        roundtrip_bits::<(u32, u32)>(bits)?;
        roundtrip_bits::<(i32, i32)>(bits)?;
        roundtrip_bits::<(u32, i32)>(bits)?;
        roundtrip_bits::<(i32, u32)>(bits)?;
        Ok(())
    });
}

#[test]
fn prop_float_payloads_roundtrip_finite_values() {
    property("float payload roundtrip", 200, |g| {
        let a = g.f64_in(-1e30, 1e30);
        let f1 = a as f32;
        prop_assert_eq!(f32::from_bits64(f1.to_bits64()), f1, "f32 {f1}");
        prop_assert_eq!(f64::from_bits64(a.to_bits64()), a, "f64 {a}");
        let pair = (f1, g.rng.next_u64() as u32);
        prop_assert_eq!(<(f32, u32)>::from_bits64(pair.to_bits64()), pair, "(f32,u32) {pair:?}");
        let ff = (f1, -f1);
        prop_assert_eq!(<(f32, f32)>::from_bits64(ff.to_bits64()), ff, "(f32,f32) {ff:?}");
        Ok(())
    });
}

#[test]
fn float_payload_special_values() {
    for x in [f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, f32::MIN_POSITIVE] {
        assert_eq!(f32::from_bits64(x.to_bits64()).to_bits(), x.to_bits());
    }
    assert!(f32::from_bits64(f32::NAN.to_bits64()).is_nan());
    assert!(f64::from_bits64(f64::NAN.to_bits64()).is_nan());
}

// ---------------------------------------------------------------
// 2. Weighted apply_weight path: SC vs DC vs serial parity
// ---------------------------------------------------------------

fn weighted_rmat(scale: u32, seed: u64) -> Arc<Graph> {
    let base = gen::rmat(scale, Default::default(), false);
    Arc::new(gen::with_uniform_weights(&base, 0.5, 4.0, seed))
}

/// Min-updates are order-independent and DC's extra stale candidates
/// can never win, so the three mode policies must agree *bitwise* on a
/// weighted graph — stronger than the existing tolerance checks, and
/// the first per-mode exercise of `apply_weight` on both the SC
/// per-edge path and the DC scratch-replay path.
#[test]
fn weighted_sssp_bitwise_identical_across_modes_and_serial_close() {
    let g = weighted_rmat(10, 33);
    let reference = serial::sssp_dijkstra(&g, 0);
    let mut per_mode: Vec<Vec<u32>> = Vec::new();
    for mode in [ModePolicy::Hybrid, ModePolicy::ForceSc, ModePolicy::ForceDc] {
        let session = EngineSession::new(
            g.clone(),
            PpmConfig { threads: 3, mode, k: Some(12), ..Default::default() },
        );
        let report = Runner::on(&session).run(Sssp::new(g.n(), 0));
        assert!(report.converged, "mode {mode:?}");
        for v in 0..g.n() {
            if reference[v].is_finite() {
                assert!(
                    (report.output[v] - reference[v]).abs() < 1e-3,
                    "mode {mode:?}, v={v}: {} vs serial {}",
                    report.output[v],
                    reference[v]
                );
            } else {
                assert!(report.output[v].is_infinite(), "mode {mode:?}, v={v}");
            }
        }
        per_mode.push(report.output.iter().map(|x| x.to_bits()).collect());
    }
    assert_eq!(per_mode[0], per_mode[1], "hybrid vs forced-SC distances");
    assert_eq!(per_mode[0], per_mode[2], "hybrid vs forced-DC distances");
}

/// Same parity for the 2-lane program: the parent lane must not perturb
/// the distance lane in any mode.
#[test]
fn weighted_sssp_parents_distances_identical_across_modes() {
    let g = weighted_rmat(9, 7);
    let mut per_mode: Vec<Vec<u32>> = Vec::new();
    for mode in [ModePolicy::Hybrid, ModePolicy::ForceSc, ModePolicy::ForceDc] {
        let session = EngineSession::new(
            g.clone(),
            PpmConfig { threads: 2, mode, k: Some(8), ..Default::default() },
        );
        let report = Runner::on(&session).run(SsspParents::new(g.n(), 0));
        assert!(report.converged, "mode {mode:?}");
        per_mode.push(report.output.distance.iter().map(|x| x.to_bits()).collect());
    }
    assert_eq!(per_mode[0], per_mode[1]);
    assert_eq!(per_mode[0], per_mode[2]);
}

// ---------------------------------------------------------------
// 3. Two-lane algorithms end-to-end
// ---------------------------------------------------------------

/// One session serves 1-lane (Bfs, Sssp) and 2-lane (SsspParents)
/// queries back to back: the pooled engine's bins and DC scratch are
/// reused across payload widths, and results stay correct in both
/// directions (narrow → wide → narrow).
#[test]
fn pooled_engine_is_shared_across_lane_widths() {
    let g = weighted_rmat(9, 21);
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 2, k: Some(10), ..Default::default() });
    let runner = Runner::on(&session);

    let bfs1 = runner.run(Bfs::new(g.n(), 0));
    let wide = runner.run(SsspParents::new(g.n(), 0));
    let narrow = runner.run(Sssp::new(g.n(), 0));
    assert_eq!(session.pooled_engines(), 1, "all three queries share one engine");

    // Narrow-after-wide must agree with the wide run's distance lane.
    let wide_bits: Vec<u32> = wide.output.distance.iter().map(|x| x.to_bits()).collect();
    let narrow_bits: Vec<u32> = narrow.output.iter().map(|x| x.to_bits()).collect();
    assert_eq!(narrow_bits, wide_bits);

    // BFS reachability agrees with SSSP reachability on the same graph.
    for v in 0..g.n() {
        assert_eq!(
            bfs1.output[v] >= 0,
            wide.output.distance[v].is_finite(),
            "reachability mismatch at v={v}"
        );
    }
}

#[test]
fn sssp_parents_tree_validates_against_dijkstra() {
    let g = weighted_rmat(10, 5);
    let (ref_dist, _ref_parent) = serial::sssp_dijkstra_parents(&g, 3);
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 4, k: Some(16), ..Default::default() });
    let report = Runner::on(&session).run(SsspParents::new(g.n(), 3));
    assert!(report.converged);
    let out = &report.output;
    for v in 0..g.n() {
        if !ref_dist[v].is_finite() {
            assert!(out.distance[v].is_infinite(), "v={v} should be unreached");
            assert_eq!(out.parent[v], NO_PARENT);
        } else {
            assert!(
                (out.distance[v] - ref_dist[v]).abs() < 1e-3,
                "v={v}: {} vs {}",
                out.distance[v],
                ref_dist[v]
            );
        }
    }
    // Parent trees may legitimately differ from Dijkstra's between
    // equally-short paths; validate structurally instead (the shared
    // validator checks edges exist and close the distance equation).
    validate_tree(&g, 3, &out.distance, &out.parent, 1e-3).unwrap();
}

#[test]
fn kcore_matches_serial_peeling_on_rmat_and_er() {
    let workloads = [
        Arc::new(gen::symmetrized(&gen::rmat(9, Default::default(), false))),
        Arc::new(gen::symmetrized(&gen::erdos_renyi(500, 3000, 17))),
    ];
    for g in workloads {
        let want = serial::kcore(&g);
        for mode in [ModePolicy::Hybrid, ModePolicy::ForceSc, ModePolicy::ForceDc] {
            let session = EngineSession::new(
                g.clone(),
                PpmConfig { threads: 3, mode, k: Some(8), ..Default::default() },
            );
            let report = Runner::on(&session).run(KCore::new(&g));
            assert!(report.converged, "mode {mode:?}: peeling must drain the frontier");
            assert_eq!(report.output, want, "mode {mode:?}");
        }
    }
}

/// The acceptance shape for FrontierEmpty-driven peeling: a run that is
/// budget-capped before completion reports `converged = false`.
#[test]
fn kcore_budget_cap_reports_unconverged() {
    use gpop::api::Convergence;
    let g = Arc::new(gen::symmetrized(&gen::erdos_renyi(300, 2400, 9)));
    let session = EngineSession::new(g.clone(), PpmConfig::with_threads(2));
    let report = Runner::on(&session).until(Convergence::MaxIters(1)).run(KCore::new(&g));
    assert!(!report.converged);
    assert_eq!(report.n_iters(), 1);
}
