//! Cross-module integration tests: CLI → engine → apps → IO → cachesim
//! → PJRT, exercising the paths a user actually takes.

use std::sync::Arc;

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{self, bfs};
use gpop::baselines::serial;
use gpop::coordinator::{self, GraphSpec};
use gpop::graph::{gen, io};
use gpop::ppm::{ModePolicy, PpmConfig};

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("gpop_it_{}_{name}", std::process::id()));
    p
}

#[test]
fn cli_full_pipeline_gen_then_run() {
    // gen a graph to disk, run three apps on it through the CLI layer.
    let path = tmp("pipeline.bin");
    let rc = coordinator::dispatch(
        ["gen", "--graph", "rmat:10", "--out", path.to_str().unwrap()]
            .map(String::from)
            .to_vec(),
    )
    .unwrap();
    assert_eq!(rc, 0);
    let spec = format!("file:{}", path.display());
    for app in ["bfs", "pr", "cc"] {
        let rc = coordinator::dispatch(
            ["run", "--app", app, "--graph", &spec, "--threads", "2", "--iters", "3"]
                .map(String::from)
                .to_vec(),
        )
        .unwrap();
        assert_eq!(rc, 0, "app {app}");
    }
    std::fs::remove_file(path).unwrap();
}

#[test]
fn cli_config_file_supplies_defaults() {
    let cfg = tmp("run.conf");
    std::fs::write(&cfg, "app = pr\ngraph = er:100:400\niters = 2\nthreads = 2\n").unwrap();
    let rc = coordinator::dispatch(
        ["run", "--config", cfg.to_str().unwrap()].map(String::from).to_vec(),
    )
    .unwrap();
    assert_eq!(rc, 0);
    // CLI overrides the config value.
    let rc = coordinator::dispatch(
        ["run", "--config", cfg.to_str().unwrap(), "--app", "bfs"]
            .map(String::from)
            .to_vec(),
    )
    .unwrap();
    assert_eq!(rc, 0);
    // Missing config file is an error.
    assert!(coordinator::dispatch(
        ["run", "--config", "/no/such.conf"].map(String::from).to_vec()
    )
    .is_err());
    std::fs::remove_file(cfg).unwrap();
}

#[test]
fn cli_help_and_info() {
    assert_eq!(coordinator::dispatch(vec!["help".into()]).unwrap(), 0);
    assert_eq!(coordinator::dispatch(vec!["info".into()]).unwrap(), 0);
    assert_eq!(coordinator::dispatch(vec![]).unwrap(), 2);
}

#[test]
fn cli_cachesim_all_apps() {
    for app in ["pr", "cc", "sssp"] {
        let graph = if app == "sssp" { "rmat:9+w:1:4" } else { "rmat:9" };
        let rc = coordinator::dispatch(
            ["cachesim", "--app", app, "--graph", graph, "--iters", "2", "--cache-kb", "16"]
                .map(String::from)
                .to_vec(),
        )
        .unwrap();
        assert_eq!(rc, 0, "app {app}");
    }
}

#[test]
fn spec_roundtrips_through_both_io_formats() {
    let g = GraphSpec::parse("rmat:9+w:1:3").unwrap().build().unwrap();
    let bin = tmp("roundtrip.bin");
    let el = tmp("roundtrip.el");
    io::write_binary(&g, &bin).unwrap();
    io::write_edge_list(&g, &el).unwrap();
    let g_bin = io::read_binary(&bin).unwrap();
    let g_el = io::read_edge_list(&el).unwrap();
    assert_eq!(g_bin.out().targets(), g.out().targets());
    assert_eq!(g_el.m(), g.m());
    // Engines over all three must agree.
    let sssp_on = |g: gpop::graph::Graph| {
        let n = g.n();
        let session = EngineSession::new(g, PpmConfig::default());
        Runner::on(&session).run(apps::Sssp::new(n, 0)).output
    };
    let d0 = sssp_on(g);
    let d1 = sssp_on(g_bin);
    let d2 = sssp_on(g_el);
    assert_eq!(d0, d1);
    for (a, b) in d0.iter().zip(&d2) {
        // Edge-list text loses a little float precision.
        assert!((a - b).abs() < 1e-3 || (a.is_infinite() && b.is_infinite()));
    }
    std::fs::remove_file(bin).unwrap();
    std::fs::remove_file(el).unwrap();
}

#[test]
fn one_session_runs_every_app_sequentially() {
    // The documented usage pattern: pay pre-processing once, run many
    // algorithms (paper §5 Nibble amortization argument). One session,
    // one layout build, four different algorithms.
    let g = Arc::new(gen::rmat(11, Default::default(), false));
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 3, ..Default::default() });
    let builds_before = gpop::ppm::layout_builds();

    let pr = Runner::on(&session)
        .until(Convergence::MaxIters(5))
        .run(apps::PageRank::new(&g, 0.85));
    let serial_pr = serial::pagerank(&g, 0.85, 5);
    for v in 0..g.n() {
        assert!((pr.output[v] as f64 - serial_pr[v]).abs() < 1e-5);
    }

    let bfs_rep = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
    assert_eq!(
        bfs::levels(&bfs_rep.output, 0),
        serial::bfs_levels(&g, 0),
        "BFS after PageRank on the same session"
    );

    let cc_rep = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(10_000))
        .run(apps::LabelProp::new(g.n()));
    assert_eq!(cc_rep.output, serial::label_propagation(&g));

    let nib = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(30))
        .run(apps::Nibble::new(&g, 1e-4, &[3]));
    let serial_nib = serial::nibble(&g, &[3], 1e-4, 30);
    for v in 0..g.n() {
        assert!((nib.output.pr[v] as f64 - serial_nib[v]).abs() < 1e-4);
    }

    let kc = Runner::on(&session).run(apps::KCore::new(&g));
    assert!(kc.converged, "peeling must drain the frontier");
    assert_eq!(
        kc.output,
        serial::kcore(&g),
        "k-core (out-degree variant on this directed graph) after Nibble"
    );

    assert_eq!(
        gpop::ppm::layout_builds(),
        builds_before,
        "five apps on one session must not re-run pre-processing"
    );
}

#[test]
fn mode_ablation_consistency_on_one_workload() {
    // Fig. 9's premise: the three policies agree on results while
    // differing in how they traverse.
    let g = Arc::new(gen::rmat(12, Default::default(), false));
    // One session serves all three policies via Runner::policy.
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 2, ..Default::default() });
    let mut results = Vec::new();
    for mode in [ModePolicy::ForceSc, ModePolicy::ForceDc, ModePolicy::Hybrid] {
        let res = Runner::on(&session)
            .policy(mode)
            .until(Convergence::FrontierEmpty.or_max_iters(10_000))
            .run(apps::LabelProp::new(g.n()));
        // DC mode must never be reported under ForceSc and vice versa.
        match mode {
            ModePolicy::ForceSc => {
                assert!(res.iters.iter().all(|i| i.dc_parts == 0))
            }
            ModePolicy::ForceDc => {
                assert!(res.iters.iter().all(|i| i.sc_parts == 0))
            }
            ModePolicy::Hybrid => {}
        }
        results.push(res.output);
    }
    assert_eq!(results[0], results[1]);
    assert_eq!(results[1], results[2]);
}

#[test]
fn cachesim_gpop_advantage_on_real_histories() {
    // End-to-end Tables 4/5 shape on a graph whose vertex data exceeds
    // the simulated 16 KB cache.
    use gpop::cachesim::model::{labelprop_history, pagerank_history, simulate, Framework};
    use gpop::cachesim::CacheConfig;
    let g = gen::rmat(14, Default::default(), false);
    let cache = CacheConfig { size_bytes: 16 * 1024, line_bytes: 64, ways: 8 };
    let pr_h = pagerank_history(&g, 3);
    let lp_h = labelprop_history(&g);
    for h in [&pr_h, &lp_h] {
        let gpop = simulate(&g, Framework::Gpop, h, cache, 8);
        let ligra = simulate(&g, Framework::Ligra, h, cache, 8);
        assert!(ligra > gpop, "ligra {ligra} <= gpop {gpop}");
    }
}

#[test]
fn pjrt_artifacts_integration_when_built() {
    // Full three-layer path (skips gracefully when artifacts absent;
    // `make test` always builds them first).
    let dir = gpop::runtime::pjrt::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = gpop::runtime::PjrtRuntime::new(&dir).unwrap();
    let m = rt.manifest.clone();
    let g = gen::erdos_renyi(m.n, m.n * 4, 7);
    let (blocks, inv_deg) = gpop::runtime::pjrt::graph_to_blocks(&g, m.k, m.q);
    let rank0 = vec![1.0f32 / m.n as f32; m.n];
    let exe = rt.pagerank().unwrap();
    // Fused executable == repeated single steps == native engine.
    let fused = exe.run(&blocks, &rank0, &inv_deg, 0.85).unwrap();
    let mut stepped = rank0.clone();
    for _ in 0..m.iters {
        stepped = exe.step(&blocks, &stepped, &inv_deg, 0.85).unwrap();
    }
    let session = EngineSession::new(g, PpmConfig::with_threads(2));
    let native = Runner::on(&session)
        .until(Convergence::MaxIters(m.iters))
        .run(apps::PageRank::new(&session.graph(), 0.85));
    for v in 0..m.n {
        assert!((fused[v] - stepped[v]).abs() < 1e-6);
        assert!((fused[v] - native.output[v]).abs() < 1e-4);
    }
}
