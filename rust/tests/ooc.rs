//! Out-of-core execution (PR 7): paged runs must be *bit-identical* to
//! in-memory runs of the same configuration, across memory budgets that
//! force the cache from "everything resident" down to heavy eviction
//! churn — and the budget must actually bound the resident set.
//!
//! The matrix: {PageRank, BFS, SSSP-parents} × budgets {∞, ½, ¼, ⅛ of
//! the total row bytes} × k ∈ {4, 16, 64} × threads ∈ {1, 4}, one
//! shared paged session (and therefore one shared cache) per budget.

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{Bfs, PageRank, SsspParents};
use gpop::graph::{gen, io::write_binary, Graph};
use gpop::ooc::{PartitionStore, RowKey};
use gpop::ppm::PpmConfig;
use std::path::PathBuf;

/// Persist the two artifacts a paged session mounts: the binary graph
/// and the prebuilt layout (written through the session save path, so
/// the file is exactly what a warm restart would load).
fn artifacts(g: &Graph, config: &PpmConfig, name: &str) -> (PathBuf, PathBuf) {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let gp = dir.join(format!("gpop_ooc_it_{pid}_{name}.bin"));
    let lp = dir.join(format!("gpop_ooc_it_{pid}_{name}.layout"));
    write_binary(g, &gp).unwrap();
    let session = EngineSession::new(g.clone(), config.clone());
    session.save(&lp).unwrap();
    (gp, lp)
}

/// The weighted test graph: RMAT so partition sizes are skewed (hubs
/// make some rows much bigger than others — the interesting case for
/// an LRU over heterogeneous row sizes).
fn graph() -> Graph {
    gen::with_uniform_weights(&gen::rmat(10, Default::default(), true), 1.0, 4.0, 7)
}

fn pagerank(session: &EngineSession, iters: usize) -> Vec<f32> {
    Runner::on(session)
        .until(Convergence::MaxIters(iters))
        .run(PageRank::new(&session.graph(), 0.85))
        .output
}

fn bfs(session: &EngineSession, root: u32) -> Vec<i32> {
    Runner::on(session).run(Bfs::new(session.graph().n(), root)).output
}

fn sssp_parents(session: &EngineSession, root: u32) -> (Vec<f32>, Vec<u32>) {
    let out = Runner::on(session).run(SsspParents::new(session.graph().n(), root)).output;
    (out.distance, out.parent)
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn paged_matches_in_memory_bit_for_bit_across_budgets() {
    let g = graph();
    for k in [4usize, 16, 64] {
        let config = PpmConfig { k: Some(k), ..Default::default() };
        let (gp, lp) = artifacts(&g, &config, &format!("sweep_k{k}"));
        let total = {
            let store = PartitionStore::open(&gp, &lp, &config).unwrap();
            store.total_row_bytes()
        };
        for threads in [1usize, 4] {
            let config = PpmConfig { k: Some(k), threads, ..Default::default() };
            let mem = EngineSession::new(g.clone(), config.clone());
            let want_pr = pagerank(&mem, 5);
            let want_bfs = bfs(&mem, 0);
            let (want_dist, want_par) = sssp_parents(&mem, 0);
            for budget in [None, Some(total / 2), Some(total / 4), Some(total / 8)] {
                let config = PpmConfig { mem_budget: budget, ..config.clone() };
                let paged = EngineSession::open_paged(&gp, &lp, config).unwrap();
                let ctx = format!("k={k} threads={threads} budget={budget:?}");
                assert!(bits_eq(&pagerank(&paged, 5), &want_pr), "pagerank diverged: {ctx}");
                assert_eq!(bfs(&paged, 0), want_bfs, "bfs diverged: {ctx}");
                let (dist, par) = sssp_parents(&paged, 0);
                assert!(bits_eq(&dist, &want_dist), "sssp distances diverged: {ctx}");
                assert_eq!(par, want_par, "sssp parents diverged: {ctx}");
                let stats = paged.ooc_stats().unwrap();
                assert!(stats.faults > 0, "paged runs must page: {ctx}");
                if let (Some(b), 0) = (budget, stats.over_budget) {
                    assert!(
                        stats.resident_peak <= b,
                        "resident peak {} exceeds budget {b} without an over-budget \
                         event: {ctx}",
                        stats.resident_peak
                    );
                }
                if budget == Some(total / 8) {
                    assert!(stats.evictions > 0, "an 8x-over graph must evict: {ctx}");
                }
            }
        }
        std::fs::remove_file(&gp).unwrap();
        std::fs::remove_file(&lp).unwrap();
    }
}

/// The headline acceptance claim, pinned tightly at `threads = 1`: on a
/// graph whose pageable bytes exceed the budget by at least 4x, the
/// cache keeps the resident set under the cap the whole run (zero
/// over-budget events — single-threaded execution pins at most one row
/// per phase, so the cap is always satisfiable), while evicting and
/// re-faulting its way through both a PageRank and a BFS whose outputs
/// stay bit-identical to in-memory execution.
#[test]
fn budget_is_enforced_on_a_graph_4x_the_cap() {
    let g = graph();
    let config = PpmConfig { k: Some(64), threads: 1, ..Default::default() };
    let (gp, lp) = artifacts(&g, &config, "enforce");
    let store = PartitionStore::open(&gp, &lp, &config).unwrap();
    let total = store.total_row_bytes();
    let max_row = (0..store.k() as u32)
        .flat_map(|p| [RowKey::Csr(p), RowKey::Scatter(p), RowKey::Gather(p)])
        .map(|key| store.row_bytes(key))
        .max()
        .unwrap();
    let budget = total / 4;
    assert!(total >= 4 * budget, "graph must exceed the budget 4x");
    assert!(budget >= 2 * max_row, "budget must fit any two rows (k = 64 keeps rows small)");
    drop(store);
    let ooc_config = PpmConfig { mem_budget: Some(budget), ..config.clone() };
    let paged = EngineSession::open_paged(&gp, &lp, ooc_config).unwrap();
    let mem = EngineSession::new(g, config);
    assert!(bits_eq(&pagerank(&paged, 5), &pagerank(&mem, 5)));
    assert_eq!(bfs(&paged, 0), bfs(&mem, 0));
    let stats = paged.ooc_stats().unwrap();
    assert_eq!(stats.over_budget, 0, "t=1 under a 2-row budget never needs to overshoot");
    assert!(stats.resident_peak <= budget, "the cap must hold: {stats}");
    assert!(stats.resident_bytes <= budget);
    assert!(stats.evictions > 0, "4x over budget forces eviction");
    assert!(stats.faults > 64, "re-faulting evicted rows is the price of the cap");
    std::fs::remove_file(&gp).unwrap();
    std::fs::remove_file(&lp).unwrap();
}

/// Corrupt or mismatched artifacts must fail `open_paged` with
/// `InvalidData`/`InvalidInput` — never serve wrong rows.
#[test]
fn open_paged_rejects_bad_artifacts() {
    let g = graph();
    let config = PpmConfig { k: Some(8), ..Default::default() };
    let (gp, lp) = artifacts(&g, &config, "reject");
    // Wrong k: the layout fingerprint no longer matches the config.
    let wrong_k = PpmConfig { k: Some(9), mem_budget: Some(1 << 20), ..Default::default() };
    assert!(EngineSession::open_paged(&gp, &lp, wrong_k).is_err());
    // Flipped adjacency byte: the graph digest bound into the layout
    // no longer matches the mapped graph file.
    let mut bytes = std::fs::read(&gp).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(&gp, &bytes).unwrap();
    let err = EngineSession::open_paged(&gp, &lp, config).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    std::fs::remove_file(&gp).unwrap();
    std::fs::remove_file(&lp).unwrap();
}
