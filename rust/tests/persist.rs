//! Layout persistence tests: save→load bit-identity against fresh
//! `build_par` layouts (property-tested across random graphs, k and
//! payload widths), warm-restarted sessions answering queries
//! bit-identical to fresh ones without re-running the `O(E)` scan, and
//! an adversarial corrupt-file suite mirroring the `read_binary` one —
//! every corrupted fixture must surface as `InvalidData` before any
//! count-derived allocation, never as a panic.

#[path = "prop_framework/mod.rs"]
mod prop_framework;

use std::path::PathBuf;
use std::sync::Arc;

use gpop::api::{EngineSession, Runner};
use gpop::apps;
use gpop::exec::ThreadPool;
use gpop::graph::{gen, io, Graph};
use gpop::ppm::{layout_builds, BinLayout, PpmConfig, PreprocessSource};
use prop_framework::property;

fn tmp(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("gpop_persist_{}_{name}", std::process::id()));
    p
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------
// Roundtrip bit-identity
// ---------------------------------------------------------------------

#[test]
fn prop_save_load_is_bit_identical_to_build_par() {
    property("BinLayout::load == build_par", 12, |g| {
        let graph = g.graph(400, 8);
        let k = *g.pick(&[4usize, 16, 64]);
        let threads = *g.pick(&[1usize, 2, 4]);
        let config = PpmConfig { k: Some(k), ..Default::default() };
        let parts = config.partitioner(graph.n());
        let mut pool = ThreadPool::new(threads);
        let fresh = BinLayout::build_par(&graph, &parts, &mut pool);
        let path = tmp(&format!("prop_{}", g.rng.next_u64()));
        fresh.save(&path, &graph, &parts, &config).map_err(|e| e.to_string())?;
        let before = layout_builds();
        let loaded = BinLayout::load(&path, &graph, &parts, &config).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        prop_assert_eq!(layout_builds(), before, "load must not run the O(E) scan");
        prop_assert!(
            loaded == fresh,
            "loaded layout diverged (n={}, m={}, weighted={}, k={k}, t={threads})",
            graph.n(),
            graph.m(),
            graph.is_weighted()
        );
        Ok(())
    });
}

#[test]
fn named_dataset_roundtrips_across_k() {
    let rmat_w = gen::with_uniform_weights(&gen::rmat(8, Default::default(), false), 1.0, 4.0, 3);
    for (graph, name) in [
        (gen::rmat(9, Default::default(), false), "rmat9"),
        (gen::erdos_renyi(600, 4800, 5), "er600"),
        (rmat_w, "rmat8+w"),
    ] {
        for k in [4usize, 16, 64] {
            let config = PpmConfig { k: Some(k), ..Default::default() };
            let parts = config.partitioner(graph.n());
            let fresh = BinLayout::build(&graph, &parts);
            let path = tmp(&format!("named_{name}_{k}"));
            fresh.save(&path, &graph, &parts, &config).unwrap();
            let loaded = BinLayout::load(&path, &graph, &parts, &config).unwrap();
            std::fs::remove_file(&path).unwrap();
            assert!(loaded == fresh, "{name} k={k}: loaded layout diverged");
        }
    }
}

// ---------------------------------------------------------------------
// Warm-restarted sessions
// ---------------------------------------------------------------------

#[test]
fn restored_session_matches_fresh_session_bitwise() {
    // threads = 1 makes gather order deterministic, so whole outputs can
    // be compared bit-for-bit across 1-lane (PageRank f32, BFS i32) and
    // 2-lane (SsspParents (f32, u32)) programs.
    let base = gen::rmat(9, Default::default(), false);
    let weighted = gen::with_uniform_weights(&base, 1.0, 4.0, 7);
    for (graph, wname) in [(base, "unweighted"), (weighted, "weighted")] {
        let g = Arc::new(graph);
        let config = PpmConfig { threads: 1, k: Some(16), ..Default::default() };
        let fresh = EngineSession::new(g.clone(), config.clone());
        let path = tmp(&format!("sess_{wname}"));
        fresh.save(&path).unwrap();
        let warm = EngineSession::restore(g.clone(), config, &path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(warm.build_stats().source, PreprocessSource::Loaded);
        assert!(*warm.layout() == *fresh.layout(), "{wname}: restored layout diverged");

        let pr_a = Runner::on(&fresh).run(apps::PageRank::new(&g, 0.85));
        let pr_b = Runner::on(&warm).run(apps::PageRank::new(&g, 0.85));
        assert_eq!(bits(&pr_a.output), bits(&pr_b.output), "{wname}: PageRank diverged");
        assert_eq!(pr_a.preprocess, PreprocessSource::Built);
        assert_eq!(pr_b.preprocess, PreprocessSource::Loaded);

        let bfs_a = Runner::on(&fresh).run(apps::Bfs::new(g.n(), 0));
        let bfs_b = Runner::on(&warm).run(apps::Bfs::new(g.n(), 0));
        assert_eq!(bfs_a.output, bfs_b.output, "{wname}: BFS parents diverged");

        if g.is_weighted() {
            let sp_a = Runner::on(&fresh).run(apps::SsspParents::new(g.n(), 0));
            let sp_b = Runner::on(&warm).run(apps::SsspParents::new(g.n(), 0));
            assert_eq!(
                bits(&sp_a.output.distance),
                bits(&sp_b.output.distance),
                "{wname}: 2-lane distances diverged"
            );
            assert_eq!(sp_a.output.parent, sp_b.output.parent, "{wname}: parents diverged");
        }
    }
}

#[test]
fn restored_session_answers_match_at_higher_thread_counts() {
    // At t = 4 gather interleavings are nondeterministic, but f32
    // min-combining is order-independent, so SSSP distances must still
    // agree bit-for-bit between a fresh and a restored session.
    let g = Arc::new(gen::with_uniform_weights(&gen::erdos_renyi(500, 4000, 11), 1.0, 4.0, 5));
    let config = PpmConfig { threads: 4, k: Some(16), ..Default::default() };
    let fresh = EngineSession::new(g.clone(), config.clone());
    let path = tmp("t4");
    fresh.save(&path).unwrap();
    let warm = EngineSession::restore(g.clone(), config, &path).unwrap();
    std::fs::remove_file(&path).unwrap();
    let a = Runner::on(&fresh).run(apps::Sssp::new(g.n(), 0));
    let b = Runner::on(&warm).run(apps::Sssp::new(g.n(), 0));
    assert_eq!(bits(&a.output), bits(&b.output), "SSSP distances diverged at t=4");
}

#[test]
fn restore_skips_the_scan_and_amortizes_queries() {
    let g = Arc::new(gen::erdos_renyi(400, 3200, 9));
    let config = PpmConfig { threads: 2, k: Some(8), ..Default::default() };
    let path = tmp("amort");
    EngineSession::new(g.clone(), config.clone()).save(&path).unwrap();
    let before = layout_builds();
    let warm = EngineSession::restore(g.clone(), config, &path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(layout_builds(), before, "restore must not run the O(E) scan");
    assert_eq!(warm.build_stats().source, PreprocessSource::Loaded);
    assert!(warm.build_stats().t_layout > 0.0, "the load is still timed");
    for root in [0u32, 5, 17] {
        let rep = Runner::on(&warm).run(apps::Bfs::new(g.n(), root));
        assert!(rep.converged);
        assert_eq!(rep.preprocess, PreprocessSource::Loaded, "reports must name the warm path");
        assert!(rep.t_preprocess > 0.0, "amortized load cost is surfaced per query");
    }
    assert_eq!(layout_builds(), before, "queries on a restored session never rebuild");
}

#[test]
fn whole_session_restores_from_disk() {
    // The full serving flow: graph (write_binary) + layout (save) both
    // persisted; a restart restores the session from the two files.
    let g = gen::with_uniform_weights(&gen::erdos_renyi(300, 2000, 21), 1.0, 4.0, 9);
    let gpath = tmp("whole.bin");
    let lpath = tmp("whole.layout");
    io::write_binary(&g, &gpath).unwrap();
    let config = PpmConfig { threads: 2, k: Some(8), ..Default::default() };
    let fresh = EngineSession::new(g, config.clone());
    fresh.save(&lpath).unwrap();
    drop(fresh);
    let g2 = io::read_binary(&gpath).unwrap();
    let warm = EngineSession::restore(g2, config, &lpath).unwrap();
    let rep = Runner::on(&warm).run(apps::Sssp::new(warm.graph().n(), 0));
    assert!(rep.converged);
    assert_eq!(rep.preprocess, PreprocessSource::Loaded);
    std::fs::remove_file(&gpath).unwrap();
    std::fs::remove_file(&lpath).unwrap();
}

// ---------------------------------------------------------------------
// Corrupt / mismatched files: always InvalidData, never a panic
// ---------------------------------------------------------------------

// Header byte offsets (see ppm::persist module docs): magic 0..8,
// version 8..12, fingerprint 12..20, digest 20..28, n 28..36, k 36..44,
// q 44..52, weighted 52, section totals 53..93.

fn fixture() -> (Arc<Graph>, PpmConfig, Vec<u8>) {
    // Tests run concurrently in one process: every fixture gets its own
    // scratch file.
    static FIXTURE_ID: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
    let id = FIXTURE_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let g = Arc::new(gen::erdos_renyi(120, 600, 13));
    let config = PpmConfig { k: Some(6), ..Default::default() };
    let parts = config.partitioner(g.n());
    let layout = BinLayout::build(&g, &parts);
    let path = tmp(&format!("fixture_{id}"));
    layout.save(&path, &g, &parts, &config).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    (g, config, bytes)
}

/// Corrupt the fixture bytes and expect `InvalidData` (not a panic, not
/// an abort, not a count-driven giant allocation).
fn expect_invalid(name: &str, corrupt: impl FnOnce(&mut Vec<u8>)) {
    let (g, config, mut bytes) = fixture();
    corrupt(&mut bytes);
    let path = tmp(name);
    std::fs::write(&path, &bytes).unwrap();
    let parts = config.partitioner(g.n());
    let err = BinLayout::load(&path, &g, &parts, &config).expect_err(name);
    std::fs::remove_file(&path).unwrap();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{name}: {err}");
}

#[test]
fn corrupt_truncated_file_rejected() {
    expect_invalid("trunc", |b| {
        let keep = b.len() - 10;
        b.truncate(keep);
    });
    // Shorter than even the fixed header.
    expect_invalid("trunc_header", |b| b.truncate(40));
}

#[test]
fn corrupt_wrong_magic_rejected() {
    expect_invalid("magic", |b| b[..8].copy_from_slice(b"NOTALAYT"));
}

#[test]
fn corrupt_future_format_version_rejected() {
    expect_invalid("version", |b| b[8..12].copy_from_slice(&99u32.to_le_bytes()));
}

#[test]
fn corrupt_checksum_mismatch_rejected() {
    // Flip one payload byte: the structure still parses sizes cleanly,
    // so only the checksum can catch it — and it must, before the
    // payload is interpreted.
    expect_invalid("checksum", |b| {
        let mid = b.len() / 2;
        b[mid] ^= 0xFF;
    });
}

#[test]
fn corrupt_count_overflow_rejected_before_allocating() {
    // u64::MAX section totals overflow the checked size arithmetic —
    // pre-validation this would have been a multi-EiB allocation demand.
    expect_invalid("overflow_ids", |b| b[53..61].copy_from_slice(&u64::MAX.to_le_bytes()));
    expect_invalid("overflow_np", |b| b[85..93].copy_from_slice(&u64::MAX.to_le_bytes()));
}

#[test]
fn corrupt_partitioning_and_flag_fields_rejected() {
    // Tampered k: disagrees with what the config induces.
    expect_invalid("bad_k", |b| b[36..44].copy_from_slice(&(1u64 << 40).to_le_bytes()));
    // Weight flag out of {0, 1}.
    expect_invalid("bad_flag", |b| b[52] = 7);
    // Weightedness flipped against the graph.
    expect_invalid("flipped_weighted", |b| b[52] = 1);
}

#[test]
fn mismatched_config_rejected() {
    let (g, _config, bytes) = fixture();
    let path = tmp("cfgmismatch");
    std::fs::write(&path, &bytes).unwrap();
    // Built under k = 6; loading under k = 7 must be refused up front.
    let other = PpmConfig { k: Some(7), ..Default::default() };
    let parts = other.partitioner(g.n());
    let err = BinLayout::load(&path, &g, &parts, &other).expect_err("config mismatch");
    std::fs::remove_file(&path).unwrap();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("configuration"), "got: {err}");
}

#[test]
fn mismatched_graph_rejected() {
    let (_g, config, bytes) = fixture();
    let path = tmp("graphmismatch");
    std::fs::write(&path, &bytes).unwrap();
    // Same n (so the partitioning agrees) but different edges: only the
    // digest can tell them apart, and it must.
    let other = gen::erdos_renyi(120, 600, 14);
    let parts = config.partitioner(other.n());
    let err = BinLayout::load(&path, &other, &parts, &config).expect_err("graph mismatch");
    std::fs::remove_file(&path).unwrap();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("different graph"), "got: {err}");
}

#[test]
fn session_restore_surfaces_invalid_files_as_errors() {
    // The session-level wrapper must pass InvalidData through (no panic,
    // no partial session).
    let (g, config, mut bytes) = fixture();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    let path = tmp("sess_invalid");
    std::fs::write(&path, &bytes).unwrap();
    let err = EngineSession::restore(g, config, &path).expect_err("corrupt layout");
    std::fs::remove_file(&path).unwrap();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
}
