//! A miniature property-testing framework (proptest is unavailable in
//! this offline environment — DESIGN.md §Substitutions).
//!
//! Properties run against many deterministic PRNG seeds; on failure the
//! seed is reported so the case can be replayed exactly
//! (`GPOP_PROP_SEED=<seed>`), and small inputs are tried first (cheap
//! shrinking by construction).

// Shared by several test crates; not every crate uses every generator.
#![allow(dead_code)]

use gpop::graph::{Graph, GraphBuilder};
use gpop::util::rng::Rng;
use gpop::VertexId;

/// Input generator handle for one property case.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [0.0, 1.0]; early cases are small.
    pub size: f64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Scaled upper bound: early cases draw from a smaller range.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let scaled_hi = lo + ((hi - lo) as f64 * self.size) as usize;
        self.usize_in(lo, scaled_hi.max(lo))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// A random directed graph: n in [1, max_n], ~m edges, optional
    /// weights/symmetry. Covers corner shapes (isolated vertices,
    /// self-loop-free, parallel edges kept).
    pub fn graph(&mut self, max_n: usize, max_degree: usize) -> Graph {
        let n = self.sized(1, max_n);
        let m = self.usize_in(0, n * max_degree);
        let weighted = self.bool();
        let mut b = GraphBuilder::new().with_n(n);
        if weighted {
            b = b.weighted();
        }
        for _ in 0..m {
            let s = self.rng.below(n as u64) as VertexId;
            let d = self.rng.below(n as u64) as VertexId;
            if weighted {
                b.add_weighted(s, d, 0.5 + self.rng.next_f32() * 4.0);
            } else {
                b.add(s, d);
            }
        }
        b.build()
    }

    /// Random seed vertices (non-empty, within range).
    pub fn vertices(&mut self, n: usize, max_count: usize) -> Vec<VertexId> {
        let count = self.usize_in(1, max_count.min(n).max(1));
        (0..count).map(|_| self.rng.below(n as u64) as VertexId).collect()
    }
}

/// Run `f` for `cases` seeds; panic with the failing seed on error.
pub fn property<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    // Replay a single seed when requested.
    if let Ok(seed) = std::env::var("GPOP_PROP_SEED") {
        let seed: u64 = seed.parse().expect("GPOP_PROP_SEED must be a u64");
        let mut g = Gen { rng: Rng::new(seed), size: 1.0 };
        if let Err(e) = f(&mut g) {
            panic!("property {name:?} failed on replayed seed {seed}: {e}");
        }
        return;
    }
    for i in 0..cases {
        let seed = 0xC0FFEE ^ i.wrapping_mul(0x9E3779B97F4A7C15);
        let size = ((i + 1) as f64 / cases as f64).min(1.0);
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(e) = f(&mut g) {
            panic!(
                "property {name:?} failed on case {i}/{cases} (seed {seed}):\n  {e}\n\
                 replay with GPOP_PROP_SEED={seed}"
            );
        }
    }
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)*) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{} != {}: {}", stringify!($a), stringify!($b), format!($($fmt)*)));
        }
    }};
}
