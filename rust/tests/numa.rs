//! NUMA placement (PR 9) acceptance: placement only moves pages and
//! pins threads — it must **never** change results. Pinned (`auto`),
//! unpinned (`off`) and interleaved runs of the same query are required
//! to be bit-identical across the k × threads matrix, in-memory and
//! paged, and wherever placement is unavailable (single-node CI boxes,
//! non-Linux targets, refused `sched_setaffinity`) the engine must
//! report an effective policy of `off` instead of failing.
//!
//! On a single-node machine `auto`/`interleave` plan to the no-op, so
//! the identity assertions are trivially true there — but the full
//! plan/pin/first-touch code path still runs, and on a multi-socket
//! host the same suite checks real placement.

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{Bfs, PageRank, SsspParents};
use gpop::graph::{gen, io::write_binary, Graph};
use gpop::ppm::{NumaPolicy, PpmConfig};
use std::path::PathBuf;

/// Weighted RMAT: skewed partition sizes, so placed first-touch
/// allocation sees heterogeneous rows (same graph as `tests/ooc.rs`).
fn graph() -> Graph {
    gen::with_uniform_weights(&gen::rmat(10, Default::default(), true), 1.0, 4.0, 7)
}

fn pagerank(session: &EngineSession, iters: usize) -> Vec<f32> {
    Runner::on(session)
        .until(Convergence::MaxIters(iters))
        .run(PageRank::new(&session.graph(), 0.85))
        .output
}

fn bfs(session: &EngineSession, root: u32) -> Vec<i32> {
    Runner::on(session).run(Bfs::new(session.graph().n(), root)).output
}

fn sssp_parents(session: &EngineSession, root: u32) -> (Vec<f32>, Vec<u32>) {
    let out = Runner::on(session).run(SsspParents::new(session.graph().n(), root)).output;
    (out.distance, out.parent)
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn placement_policies_are_bit_identical_across_k_and_threads() {
    let g = graph();
    for k in [4usize, 16, 64] {
        for threads in [1usize, 4] {
            let config =
                PpmConfig { k: Some(k), threads, numa: NumaPolicy::Off, ..Default::default() };
            let base = EngineSession::new(g.clone(), config.clone());
            let want_pr = pagerank(&base, 5);
            let want_bfs = bfs(&base, 0);
            let (want_dist, want_par) = sssp_parents(&base, 0);
            for policy in [NumaPolicy::Auto, NumaPolicy::Interleave] {
                let config = PpmConfig { numa: policy, ..config.clone() };
                let session = EngineSession::new(g.clone(), config);
                let ctx = format!("numa={policy} k={k} threads={threads}");
                assert!(bits_eq(&pagerank(&session, 5), &want_pr), "pagerank diverged: {ctx}");
                assert_eq!(bfs(&session, 0), want_bfs, "bfs diverged: {ctx}");
                let (dist, par) = sssp_parents(&session, 0);
                assert!(bits_eq(&dist, &want_dist), "sssp distances diverged: {ctx}");
                assert_eq!(par, want_par, "sssp parents diverged: {ctx}");
            }
        }
    }
}

/// [`BuildStats`](gpop::ppm::BuildStats) reports the *effective* policy:
/// `off` covers both an explicit request and every fallback, and an
/// active placement always names at least two nodes. A requested policy
/// must never error out — degrading is the contract.
#[test]
fn effective_policy_is_reported_and_fallback_is_a_clean_no_op() {
    let g = gen::erdos_renyi(400, 3200, 7);
    let config = |threads: usize, numa: NumaPolicy| PpmConfig {
        threads,
        k: Some(8),
        numa,
        ..Default::default()
    };
    // An explicit `off` is reported verbatim, with no nodes.
    let off = EngineSession::new(g.clone(), config(2, NumaPolicy::Off));
    assert_eq!(off.build_stats().numa, NumaPolicy::Off);
    assert_eq!(off.build_stats().numa_nodes, 0);
    // `auto`/`interleave` either activate (multi-node host: >= 2 nodes
    // reported) or degrade to a reported `off` — whatever this machine
    // is, the run completes and the stats are self-consistent.
    for requested in [NumaPolicy::Auto, NumaPolicy::Interleave] {
        let session = EngineSession::new(g.clone(), config(4, requested));
        let build = session.build_stats();
        match build.numa {
            NumaPolicy::Off => assert_eq!(build.numa_nodes, 0, "requested {requested}"),
            active => {
                assert_eq!(active, requested);
                assert!(build.numa_nodes >= 2, "active placement needs >= 2 nodes");
            }
        }
        // The degraded (or active) session still answers queries.
        assert!(!bfs(&session, 0).is_empty());
    }
    // A single-threaded pool can never activate placement: there is
    // nothing to distribute.
    let single = EngineSession::new(g, config(1, NumaPolicy::Interleave));
    assert_eq!(single.build_stats().numa, NumaPolicy::Off);
    assert_eq!(single.build_stats().numa_nodes, 0);
}

/// Paged (`--mem-budget`) sessions route row materialization through
/// the placement map (the IO thread pins to the owning node) — results
/// must stay bit-identical to the unplaced in-memory run, under real
/// eviction pressure.
#[test]
fn paged_runs_honor_placement_and_stay_bit_identical() {
    let g = graph();
    let config = PpmConfig { k: Some(16), threads: 4, ..Default::default() };
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let gp: PathBuf = dir.join(format!("gpop_numa_it_{pid}.bin"));
    let lp: PathBuf = dir.join(format!("gpop_numa_it_{pid}.layout"));
    write_binary(&g, &gp).unwrap();
    EngineSession::new(g.clone(), config.clone()).save(&lp).unwrap();
    let total = {
        let store = gpop::ooc::PartitionStore::open(&gp, &lp, &config).unwrap();
        store.total_row_bytes()
    };
    let base = EngineSession::new(g, PpmConfig { numa: NumaPolicy::Off, ..config.clone() });
    let want_pr = pagerank(&base, 5);
    let want_bfs = bfs(&base, 0);
    for policy in [NumaPolicy::Off, NumaPolicy::Auto, NumaPolicy::Interleave] {
        let config = PpmConfig { numa: policy, mem_budget: Some(total / 4), ..config.clone() };
        let paged = EngineSession::open_paged(&gp, &lp, config).unwrap();
        assert!(bits_eq(&pagerank(&paged, 5), &want_pr), "paged pagerank diverged: {policy}");
        assert_eq!(bfs(&paged, 0), want_bfs, "paged bfs diverged: {policy}");
        let stats = paged.ooc_stats().unwrap();
        assert!(stats.evictions > 0, "a 4x-over budget must evict under {policy}");
    }
    std::fs::remove_file(&gp).unwrap();
    std::fs::remove_file(&lp).unwrap();
}
