//! `gpop serve` integration tests: typed backpressure at saturation,
//! the admission gate keeping every batch on a pooled engine, served
//! answers bit-identical to direct `Runner` runs, and the socket front
//! door end to end (connect, query, stats, shutdown, cleanup).

use std::sync::Arc;

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps;
use gpop::graph::gen;
use gpop::ppm::PpmConfig;
use gpop::serve::{
    output_digest_f32s, output_digest_i32s, PR_EPS, Query, QueryOk, Response, ServeConfig,
    ServeLoop, SubmitError,
};

fn session(n: usize, threads: usize) -> Arc<EngineSession> {
    Arc::new(EngineSession::new(
        gen::erdos_renyi(n, n * 8, 33),
        PpmConfig { threads, k: Some(8), ..Default::default() },
    ))
}

fn ok(response: Response) -> QueryOk {
    match response {
        Response::Ok(ok) => ok,
        other => panic!("expected ok response, got {other:?}"),
    }
}

#[test]
fn saturation_returns_overloaded_then_recovers() {
    // Workers stay paused so the queue genuinely fills: submits 5..8
    // must shed with the typed error, not block, panic, or vanish.
    let mut sloop = ServeLoop::new(
        session(200, 1),
        ServeConfig { queue_cap: 4, batch_max: 4, workers: 1 },
    );
    let h = sloop.handle();
    let rxs: Vec<_> = (0..4u32).map(|r| h.submit(Query::Bfs { root: r }).unwrap()).collect();
    for _ in 0..3 {
        match h.submit(Query::Bfs { root: 0 }) {
            Err(SubmitError::Overloaded { capacity }) => assert_eq!(capacity, 4),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
    assert_eq!(h.stats().rejected, 3);
    sloop.start();
    for rx in rxs {
        assert!(matches!(rx.recv().unwrap(), Response::Ok(_)));
    }
    // The queue drained: admission works again without a restart.
    let rx = h.submit(Query::Bfs { root: 1 }).expect("admission recovered after drain");
    assert!(matches!(rx.recv().unwrap(), Response::Ok(_)));
}

#[test]
fn gated_load_keeps_transient_checkouts_at_zero() {
    // Four workers race over a pool of two engines. Without the
    // admission gate this load would spill into transient allocations;
    // with it, every batch reuses a pooled engine.
    let s = Arc::new(EngineSession::new(
        gen::erdos_renyi(400, 3200, 9),
        PpmConfig { threads: 1, k: Some(8), pool_cap: 2, ..Default::default() },
    ));
    let mut sloop = ServeLoop::started(
        Arc::clone(&s),
        ServeConfig { queue_cap: 256, batch_max: 4, workers: 4 },
    );
    let h = sloop.handle();
    let rxs: Vec<_> = (0..64u32)
        .map(|i| {
            let query = if i % 2 == 0 {
                Query::Bfs { root: i % 50 }
            } else {
                Query::PageRank { damping: 0.85, max_iters: 3 }
            };
            h.submit(query).expect("queue_cap 256 never fills here")
        })
        .collect();
    for rx in rxs {
        assert!(matches!(rx.recv().unwrap(), Response::Ok(_)));
    }
    assert_eq!(s.transient_checkouts(), 0, "admission gate must bound checkouts to the pool");
    assert_eq!(h.stats().completed, 64);
    sloop.shutdown();
}

#[test]
fn served_answers_match_direct_runner_bitwise() {
    let s = session(300, 1);
    let graph = s.graph();
    let mut sloop = ServeLoop::started(Arc::clone(&s), ServeConfig::default());
    let h = sloop.handle();
    let bfs = ok(h.submit_wait(Query::Bfs { root: 3 }));
    assert_eq!(bfs.algo, "bfs");
    let pr = ok(h.submit_wait(Query::PageRank { damping: 0.9, max_iters: 5 }));
    assert_eq!(pr.algo, "pr");
    sloop.shutdown();
    let direct_bfs = Runner::on(&s).run(apps::Bfs::new(graph.n(), 3));
    assert_eq!(bfs.digest, output_digest_i32s(&direct_bfs.output));
    assert_eq!(bfs.summary as usize, apps::bfs::n_reached(&direct_bfs.output));
    let direct_pr = Runner::on(&s)
        .until(Convergence::L1Norm(PR_EPS).or_max_iters(5))
        .run(apps::PageRank::new(&graph, 0.9));
    assert_eq!(pr.digest, output_digest_f32s(&direct_pr.output));
    assert_eq!(pr.iters, direct_pr.n_iters());
}

#[test]
fn sssp_serves_weighted_graphs_bitwise() {
    let wg = gen::with_uniform_weights(&gen::erdos_renyi(200, 1600, 4), 1.0, 4.0, 6);
    let s = Arc::new(EngineSession::new(
        wg,
        PpmConfig { threads: 1, k: Some(8), ..Default::default() },
    ));
    let mut sloop = ServeLoop::started(Arc::clone(&s), ServeConfig::default());
    let sssp = ok(sloop.handle().submit_wait(Query::Sssp { root: 0 }));
    assert_eq!(sssp.algo, "sssp");
    sloop.shutdown();
    let direct = Runner::on(&s).run(apps::Sssp::new(s.graph().n(), 0));
    assert_eq!(sssp.digest, output_digest_f32s(&direct.output));
}

#[cfg(unix)]
#[test]
fn unix_socket_end_to_end_bfs_pr_stats_shutdown() {
    use gpop::serve::{send_lines, Endpoint, Server, ServerSocket};
    let path = std::env::temp_dir().join(format!("gpop-serve-it-{}.sock", std::process::id()));
    let mut sloop = ServeLoop::started(session(300, 1), ServeConfig::default());
    let server = Server::new(ServerSocket::bind_unix(&path).unwrap(), sloop.handle());
    let runner = std::thread::spawn(move || server.run());
    let requests: Vec<String> = ["bfs 0", "pr", "stats", "shutdown"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let responses = send_lines(&Endpoint::Unix(path.clone()), &requests).unwrap();
    assert_eq!(responses.len(), 4);
    assert!(responses[0].starts_with("ok app=bfs "), "got: {}", responses[0]);
    assert!(responses[1].starts_with("ok app=pr "), "got: {}", responses[1]);
    assert!(responses[2].contains("\"transient_checkouts\":0"), "got: {}", responses[2]);
    assert_eq!(responses[3], "ok shutting down");
    runner.join().unwrap().unwrap();
    sloop.shutdown();
    assert!(!path.exists(), "server drop removes the socket file");
}
