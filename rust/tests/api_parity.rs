//! Parity suite for the unified `Algorithm`/`Session` API: every ported
//! app must produce **bit-identical** results through
//! `Runner`/`EngineSession` and through the legacy (deprecated)
//! `apps::*::run` free functions, on both RMAT and Erdős–Rényi
//! workloads. Also asserts the amortization contract: one session =
//! exactly one partition/bin-layout build, no matter how many queries.
//!
//! Since the typed-message-plane redesign (PR 2) this suite doubles as
//! the 1-lane payload parity proof: all eight apps here run through the
//! lane-generic bins/scratch/gather paths with `Msg::LANES = 1`, and
//! the bitwise assertions (f32 ranks, distances, diffusion vectors)
//! pin that the monomorphized 1-lane plane computes exactly what the
//! fixed 4-byte plane did — any change in message layout, cursor
//! stepping, or Eq. 1 byte accounting for `d_v = 4` breaks them.

#![allow(deprecated)]

use std::sync::Arc;

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{self, bfs};
use gpop::graph::{gen, Graph};
use gpop::ppm::{layout_builds, Engine, PpmConfig};

fn workloads() -> Vec<(&'static str, Arc<Graph>)> {
    vec![
        ("rmat10", Arc::new(gen::rmat(10, Default::default(), false))),
        ("er", Arc::new(gen::erdos_renyi(700, 5600, 33))),
    ]
}

fn weighted(g: &Graph) -> Arc<Graph> {
    Arc::new(gen::with_uniform_weights(g, 0.5, 4.0, 11))
}

fn symmetrized(g: &Graph) -> Arc<Graph> {
    Arc::new(gen::symmetrized(g))
}

/// Single-threaded: with >1 thread the bin registration order (and so
/// the f32 accumulation order) is scheduling-dependent, which makes
/// bitwise comparison meaningless even between two legacy runs. The
/// multithreaded paths are covered (within numeric tolerance) by the
/// per-app and property tests; here we pin the schedule to prove the
/// new driver executes the *identical* computation.
fn config() -> PpmConfig {
    PpmConfig { threads: 1, k: Some(12), ..Default::default() }
}

/// Drive the legacy path on a fresh engine over the same shared graph.
fn legacy_engine(g: &Arc<Graph>) -> Engine {
    Engine::new(g.clone(), config())
}

#[test]
fn bfs_report_bit_identical_to_legacy_run() {
    for (name, g) in workloads() {
        let old = apps::bfs::run(&mut legacy_engine(&g), 0);
        let session = EngineSession::new(g.clone(), config());
        let new = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
        assert_eq!(new.output, old.parent, "{name}");
        assert_eq!(new.converged, old.stats.converged, "{name}");
        assert_eq!(new.n_iters(), old.stats.n_iters(), "{name}");
        assert_eq!(new.total_messages(), old.stats.total_messages(), "{name}");
    }
}

#[test]
fn pagerank_report_bit_identical_to_legacy_run() {
    for (name, g) in workloads() {
        let old = apps::pagerank::run(&mut legacy_engine(&g), 0.85, 10);
        let session = EngineSession::new(g.clone(), config());
        let new = Runner::on(&session)
            .until(Convergence::MaxIters(10))
            .run(apps::PageRank::new(&g, 0.85));
        // f32 ranks must agree bit-for-bit: same engine, same schedule.
        let old_bits: Vec<u32> = old.rank.iter().map(|x| x.to_bits()).collect();
        let new_bits: Vec<u32> = new.output.iter().map(|x| x.to_bits()).collect();
        assert_eq!(new_bits, old_bits, "{name}");
        assert_eq!(new.n_iters(), old.iters.len(), "{name}");
    }
}

#[test]
fn cc_and_async_cc_bit_identical_to_legacy_run() {
    for (name, g) in workloads() {
        let sg = symmetrized(&g);
        let old = apps::cc::run(&mut legacy_engine(&sg), 10_000);
        let session = EngineSession::new(sg.clone(), config());
        let until = Convergence::FrontierEmpty.or_max_iters(10_000);
        let new = Runner::on(&session).until(until.clone()).run(apps::LabelProp::new(sg.n()));
        assert_eq!(new.output, old.label, "{name}");
        assert_eq!(new.n_iters(), old.stats.n_iters(), "{name}");

        let old_a = apps::cc_async::run(&mut legacy_engine(&sg), 10_000);
        let new_a = Runner::on(&session).until(until).run(apps::AsyncLabelProp::new(sg.n()));
        assert_eq!(new_a.output, old_a.label, "{name} async");
        assert_eq!(new_a.n_iters(), old_a.stats.n_iters(), "{name} async");
    }
}

#[test]
fn sssp_report_bit_identical_to_legacy_run() {
    for (name, g) in workloads() {
        let wg = weighted(&g);
        let old = apps::sssp::run(&mut legacy_engine(&wg), 0);
        let session = EngineSession::new(wg.clone(), config());
        let new = Runner::on(&session).run(apps::Sssp::new(wg.n(), 0));
        let old_bits: Vec<u32> = old.distance.iter().map(|x| x.to_bits()).collect();
        let new_bits: Vec<u32> = new.output.iter().map(|x| x.to_bits()).collect();
        assert_eq!(new_bits, old_bits, "{name}");
        assert_eq!(new.n_iters(), old.stats.n_iters(), "{name}");
    }
}

#[test]
fn nibble_family_bit_identical_to_legacy_run() {
    for (name, g) in workloads() {
        let session = EngineSession::new(g.clone(), config());
        let until = Convergence::FrontierEmpty.or_max_iters(40);

        let old = apps::nibble::run(&mut legacy_engine(&g), &[3], 1e-5, 40);
        let new = Runner::on(&session).until(until.clone()).run(apps::Nibble::new(&g, 1e-5, &[3]));
        let old_bits: Vec<u32> = old.pr.iter().map(|x| x.to_bits()).collect();
        let new_bits: Vec<u32> = new.output.pr.iter().map(|x| x.to_bits()).collect();
        assert_eq!(new_bits, old_bits, "{name} nibble");
        assert_eq!(new.output.support, old.support, "{name} nibble support");

        let old_p = apps::pagerank_nibble::run(&mut legacy_engine(&g), &[3], 0.15, 1e-5, 40);
        let new_p = Runner::on(&session)
            .until(until)
            .run(apps::PageRankNibble::new(&g, 0.15, 1e-5, &[3]));
        assert_eq!(
            new_p.output.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            old_p.p.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{name} prnibble p"
        );
        assert_eq!(
            new_p.output.r.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            old_p.r.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{name} prnibble r"
        );

        let old_h = apps::heat_kernel::run(&mut legacy_engine(&g), &[3], 2.0, 8, 1e-7);
        let new_h = Runner::on(&session).run(apps::HeatKernel::new(&g, 2.0, 8, 1e-7, &[3]));
        assert_eq!(
            new_h.output.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            old_h.heat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{name} heat-kernel"
        );
        assert_eq!(new_h.n_iters(), old_h.iters, "{name} heat-kernel stages");
    }
}

#[test]
fn two_sequential_queries_do_not_repartition() {
    let g = Arc::new(gen::rmat(9, Default::default(), false));
    let session = EngineSession::new(g.clone(), config());
    let builds = layout_builds();
    let a = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
    let b = Runner::on(&session).run(apps::Bfs::new(g.n(), 5));
    assert_eq!(
        layout_builds(),
        builds,
        "sequential queries on one session must not re-partition"
    );
    assert!(a.converged && b.converged);
    // The pooled engine was reused, not rebuilt.
    assert_eq!(session.pooled_engines(), 1);
}

#[test]
fn batch_of_16_bfs_roots_partitions_exactly_once() {
    let g = Arc::new(gen::erdos_renyi(800, 6400, 77));
    let builds = layout_builds();
    let session = EngineSession::new(g.clone(), config());
    assert_eq!(layout_builds(), builds + 1, "session build = one partition pass");

    let roots: Vec<u32> = (0..16).map(|i| (i * 50) as u32).collect();
    let reports =
        Runner::on(&session).run_batch(roots.iter().map(|&r| apps::Bfs::new(g.n(), r)));
    assert_eq!(
        layout_builds(),
        builds + 1,
        "a 16-root batch must re-partition exactly once (the session build)"
    );
    assert_eq!(reports.len(), 16);
    // Each query's result matches an independent single-query run.
    for (&root, report) in roots.iter().zip(&reports) {
        let fresh = Runner::on(&session).run(apps::Bfs::new(g.n(), root));
        assert_eq!(
            bfs::levels(&report.output, root),
            bfs::levels(&fresh.output, root),
            "root {root}"
        );
    }
    // The whole batch shared ONE engine checkout.
    assert!(session.pooled_engines() >= 1);
}

#[test]
fn concurrent_sessions_queries_match_sequential() {
    // The serving scenario: one shared session, queries from many
    // threads; results must match the single-threaded answers and the
    // layout must never be rebuilt.
    let g = Arc::new(gen::erdos_renyi(500, 4000, 5));
    let session = Arc::new(EngineSession::new(g.clone(), config()));
    let builds = layout_builds();
    let want: Vec<Vec<i32>> = (0..4u32)
        .map(|r| {
            bfs::levels(&Runner::on(&session).run(apps::Bfs::new(g.n(), r * 100)).output, r * 100)
        })
        .collect();
    assert_eq!(layout_builds(), builds, "sequential warm-up must not re-partition");
    std::thread::scope(|s| {
        for (i, want_lv) in want.iter().enumerate() {
            let session = Arc::clone(&session);
            let g = Arc::clone(&g);
            s.spawn(move || {
                let root = (i as u32) * 100;
                // The build counter is thread-local: a query that
                // re-partitioned would increment it on THIS thread.
                let before = layout_builds();
                let res = Runner::on(&session).run(apps::Bfs::new(g.n(), root));
                assert_eq!(&bfs::levels(&res.output, root), want_lv, "root {root}");
                assert_eq!(
                    layout_builds(),
                    before,
                    "concurrent query must not re-partition (root {root})"
                );
            });
        }
    });
}
