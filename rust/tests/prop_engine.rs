//! Property tests over the PPM engine's core invariants (DESIGN.md
//! §Key-invariants), driven by the mini framework in `prop_framework`.

#[path = "prop_framework/mod.rs"]
mod prop_framework;

use std::sync::Arc;

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{self, bfs};
use gpop::baselines::serial;
use gpop::partition::Partitioner;
use gpop::ppm::{Engine, ModePolicy, PpmConfig};
use prop_framework::{property, Gen};

const CASES: u64 = 30;

fn random_config(g: &mut Gen, n: usize) -> PpmConfig {
    PpmConfig {
        threads: g.usize_in(1, 4),
        mode: *g.pick(&[ModePolicy::Hybrid, ModePolicy::ForceSc, ModePolicy::ForceDc]),
        bw_ratio: g.f64_in(0.5, 4.0),
        k: if g.bool() { Some(g.usize_in(1, n.max(1))) } else { None },
        ..Default::default()
    }
}

#[test]
fn prop_partitions_disjoint_and_covering() {
    property("partition disjoint+covering", 200, |g| {
        let n = g.sized(0, 10_000);
        let k = g.usize_in(1, 64);
        let p = Partitioner::with_k(n, k);
        let mut seen = vec![false; n];
        for part in 0..p.k() as u32 {
            for v in p.range(part) {
                prop_assert!(!seen[v as usize], "vertex {v} covered twice");
                seen[v as usize] = true;
                prop_assert_eq!(p.part_of(v), part, "part_of mismatch for {v}");
                prop_assert!(p.local_index(v) < p.q(), "local index out of range");
            }
        }
        prop_assert!(seen.iter().all(|&s| s), "some vertex uncovered (n={n}, k={k})");
        Ok(())
    });
}

#[test]
fn prop_mode_choice_never_changes_bfs_result() {
    // SC-only, DC-only and hybrid must agree with the serial reference:
    // the §3.3 mode decision is a pure performance choice.
    property("bfs mode-independence", CASES, |g| {
        let graph = Arc::new(g.graph(600, 8));
        let root = g.rng.below(graph.n() as u64) as u32;
        let want = serial::bfs_levels(&graph, root);
        for mode in [ModePolicy::Hybrid, ModePolicy::ForceSc, ModePolicy::ForceDc] {
            let mut cfg = random_config(g, graph.n());
            cfg.mode = mode;
            let session = EngineSession::new(graph.clone(), cfg);
            let res = Runner::on(&session).run(apps::Bfs::new(graph.n(), root));
            let got = bfs::levels(&res.output, root);
            prop_assert_eq!(got, want.clone(), "mode {mode:?}, root {root}");
        }
        Ok(())
    });
}

#[test]
fn prop_pagerank_matches_serial_any_config() {
    property("pagerank config-independence", CASES, |g| {
        let graph = Arc::new(g.graph(500, 6));
        let cfg = random_config(g, graph.n());
        let iters = g.usize_in(1, 6);
        let want = serial::pagerank(&graph, 0.85, iters);
        let session = EngineSession::new(graph.clone(), cfg.clone());
        let res = Runner::on(&session)
            .until(Convergence::MaxIters(iters))
            .run(apps::PageRank::new(&graph, 0.85));
        for v in 0..graph.n() {
            let err = (res.output[v] as f64 - want[v]).abs();
            prop_assert!(
                err < 1e-4,
                "v={v}: {} vs {} (cfg {cfg:?}, iters {iters})",
                res.output[v],
                want[v]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_cc_fixpoint_matches_serial() {
    property("labelprop fixpoint", CASES, |g| {
        let graph = Arc::new(g.graph(400, 5));
        let want = serial::label_propagation(&graph);
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let res = Runner::on(&session)
            .until(Convergence::FrontierEmpty.or_max_iters(100_000))
            .run(apps::LabelProp::new(graph.n()));
        prop_assert!(res.converged, "did not converge");
        prop_assert_eq!(res.output, want, "labels diverge");
        Ok(())
    });
}

#[test]
fn prop_sssp_matches_dijkstra() {
    property("sssp vs dijkstra", CASES, |g| {
        let base = g.graph(300, 5);
        let graph =
            Arc::new(gpop::graph::gen::with_uniform_weights(&base, 0.5, 4.0, g.rng.next_u64()));
        let src = g.rng.below(graph.n() as u64) as u32;
        let want = serial::sssp_dijkstra(&graph, src);
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let res = Runner::on(&session).run(apps::Sssp::new(graph.n(), src));
        for v in 0..graph.n() {
            if want[v].is_finite() {
                prop_assert!(
                    (res.output[v] - want[v]).abs() < 1e-3,
                    "v={v}: {} vs {}",
                    res.output[v],
                    want[v]
                );
            } else {
                prop_assert!(res.output[v].is_infinite(), "v={v} should be unreachable");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nibble_matches_serial_model() {
    property("nibble vs straight-line model", CASES, |g| {
        let graph = Arc::new(g.graph(300, 6));
        let seeds = g.vertices(graph.n(), 3);
        let eps = *g.pick(&[1e-3f32, 1e-4, 1e-5]);
        let iters = g.usize_in(1, 20);
        let want = serial::nibble(&graph, &seeds, eps as f64, iters);
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let res = Runner::on(&session)
            .until(Convergence::FrontierEmpty.or_max_iters(iters))
            .run(apps::Nibble::new(&graph, eps, &seeds));
        for v in 0..graph.n() {
            prop_assert!(
                (res.output.pr[v] as f64 - want[v]).abs() < 1e-4,
                "v={v}: {} vs {}",
                res.output.pr[v],
                want[v]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_messages_equal_active_edges_in_sc_mode() {
    // Accounting identity: unweighted SC-mode gather reads exactly one
    // message per active edge of the preceding scatter.
    property("SC message accounting", CASES, |g| {
        let graph = Arc::new(g.graph(500, 6));
        if graph.is_weighted() {
            return Ok(()); // identity below is for the unweighted layout
        }
        let mut eng = Engine::new(
            graph.clone(),
            PpmConfig {
                threads: g.usize_in(1, 4),
                mode: ModePolicy::ForceSc,
                ..Default::default()
            },
        );
        let root = g.rng.below(graph.n() as u64) as u32;
        let prog = apps::bfs::Bfs::new(graph.n(), root);
        prog.parent.set(root, root as i32);
        eng.load_frontier(&[root]);
        for _ in 0..5 {
            if eng.frontier_size() == 0 {
                break;
            }
            let fr: u64 = eng
                .frontier()
                .iter()
                .map(|&v| graph.out_degree(v) as u64)
                .sum();
            let stats = eng.iterate(&prog);
            prop_assert_eq!(stats.messages, fr, "messages != active edges");
        }
        Ok(())
    });
}

#[test]
fn prop_sssp_parents_tree_valid_any_config() {
    // The 2-lane program under random graphs/configs: distances match
    // Dijkstra, and the shared validator confirms every parent is a
    // real edge closing the distance equation.
    property("sssp-parents tree validity", CASES, |g| {
        let base = g.graph(300, 5);
        let graph =
            Arc::new(gpop::graph::gen::with_uniform_weights(&base, 0.5, 4.0, g.rng.next_u64()));
        let src = g.rng.below(graph.n() as u64) as u32;
        let want = serial::sssp_dijkstra(&graph, src);
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let res = Runner::on(&session).run(apps::SsspParents::new(graph.n(), src));
        let out = &res.output;
        for v in 0..graph.n() {
            if !want[v].is_finite() {
                prop_assert!(out.distance[v].is_infinite(), "v={v} should be unreachable");
            } else {
                prop_assert!(
                    (out.distance[v] - want[v]).abs() < 1e-3,
                    "v={v}: {} vs {}",
                    out.distance[v],
                    want[v]
                );
            }
        }
        apps::sssp_parents::validate_tree(&graph, src, &out.distance, &out.parent, 1e-3)
    });
}

#[test]
fn prop_kcore_matches_serial_any_config() {
    property("kcore vs serial peeling", CASES, |g| {
        let base = g.graph(250, 5);
        // Symmetrize for the undirected notion (weights dropped: core
        // numbers are purely structural).
        let graph = Arc::new(gpop::graph::gen::symmetrized(&base));
        let want = serial::kcore(&graph);
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let res = Runner::on(&session).run(apps::KCore::new(&graph));
        prop_assert!(res.converged, "peeling did not drain the frontier");
        prop_assert_eq!(res.output, want, "core numbers diverge");
        Ok(())
    });
}

#[test]
fn prop_session_reusable_across_runs() {
    // Running BFS twice from different roots on one session must give
    // the same answers as a fresh session (state fully reset between
    // checkouts of the pooled engine).
    property("session reuse", CASES, |g| {
        let graph = Arc::new(g.graph(300, 5));
        let r1 = g.rng.below(graph.n() as u64) as u32;
        let r2 = g.rng.below(graph.n() as u64) as u32;
        let session = EngineSession::new(graph.clone(), random_config(g, graph.n()));
        let runner = Runner::on(&session);
        let a1 = runner.run(apps::Bfs::new(graph.n(), r1));
        let a2 = runner.run(apps::Bfs::new(graph.n(), r2));
        let b2 = {
            let fresh = EngineSession::new(graph.clone(), PpmConfig::default());
            Runner::on(&fresh).run(apps::Bfs::new(graph.n(), r2))
        };
        prop_assert_eq!(
            bfs::levels(&a2.output, r2),
            bfs::levels(&b2.output, r2),
            "reused session diverged (roots {r1}, {r2})"
        );
        let _ = a1;
        Ok(())
    });
}
