//! Hot graph swap + streaming delta ingestion tests: `apply_delta`
//! layout bit-identity against from-scratch builds (property-tested
//! across random graphs, deltas, k and thread counts), torn-pair
//! freedom for checkouts racing `swap_graph`, post-swap/post-ingest
//! query bit-identity against fresh sessions, persistence of patched
//! generations under the PR 4 format, and the serve loop's
//! drain-and-flip guarantees while `swap_graph`/`ingest` land under
//! live client load.

#[path = "prop_framework/mod.rs"]
mod prop_framework;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps;
use gpop::exec::ThreadPool;
use gpop::graph::{gen, merge_delta, Graph, GraphDelta};
use gpop::ppm::{layout_builds, BinLayout, PpmConfig, PreprocessSource};
use gpop::serve::{
    output_digest_f32s, output_digest_i32s, PR_EPS, Query, Response, ServeConfig, ServeLoop,
    SubmitError,
};
use gpop::VertexId;
use prop_framework::{property, Gen};

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// A random delta against `g`: inserts (weighted iff the graph is),
/// deletes of real edges, and deletes of likely-absent edges (no-ops).
fn random_delta(g: &mut Gen, graph: &Graph) -> GraphDelta {
    let n = graph.n();
    let mut delta = GraphDelta::new();
    for _ in 0..g.usize_in(0, 12) {
        let s = g.rng.below(n as u64) as VertexId;
        let d = g.rng.below(n as u64) as VertexId;
        if graph.is_weighted() {
            delta.insert_weighted(s, d, 0.5 + g.rng.next_f32() * 4.0);
        } else {
            delta.insert(s, d);
        }
    }
    for _ in 0..g.usize_in(0, 8) {
        // Aim at a real edge: random vertex, random neighbor (falls back
        // to an arbitrary — likely absent — pair on isolated vertices).
        let s = g.rng.below(n as u64) as VertexId;
        let adj = graph.out().neighbors(s);
        let d = if adj.is_empty() {
            g.rng.below(n as u64) as VertexId
        } else {
            adj[g.rng.below(adj.len() as u64) as usize]
        };
        delta.delete(s, d);
    }
    delta
}

// ---------------------------------------------------------------------
// apply_delta bit-identity
// ---------------------------------------------------------------------

#[test]
fn prop_apply_delta_is_bit_identical_to_build_par() {
    property("BinLayout::apply_delta == build_par(merged)", 14, |g| {
        let graph = g.graph(400, 8);
        let k = *g.pick(&[4usize, 16, 64]);
        let threads = *g.pick(&[1usize, 4]);
        let delta = random_delta(g, &graph);
        let config = PpmConfig { k: Some(k), ..Default::default() };
        let parts = config.partitioner(graph.n());
        let mut pool = ThreadPool::new(threads);
        let base = BinLayout::build_par(&graph, &parts, &mut pool);
        let merged = merge_delta(&graph, &delta).map_err(|e| e.to_string())?;
        let dirty = delta.dirty_parts(&parts);
        let before = layout_builds();
        let patched = base.apply_delta(&merged, &parts, &dirty, &mut pool);
        prop_assert_eq!(layout_builds(), before, "apply_delta must not count as an O(E) scan");
        let fresh = BinLayout::build_par(&merged, &parts, &mut pool);
        prop_assert!(
            patched == fresh,
            "patched layout diverged (n={}, m={} -> {}, weighted={}, k={k}, t={threads}, \
             +{} -{} dirty={})",
            graph.n(),
            graph.m(),
            merged.m(),
            graph.is_weighted(),
            delta.inserts().len(),
            delta.deletes().len(),
            dirty.len()
        );
        Ok(())
    });
}

#[test]
fn apply_delta_named_datasets_across_k_and_threads() {
    let rmat_w = gen::with_uniform_weights(&gen::rmat(8, Default::default(), false), 1.0, 4.0, 3);
    for (graph, name) in [
        (gen::rmat(9, Default::default(), false), "rmat9"),
        (gen::erdos_renyi(600, 4800, 5), "er600"),
        (rmat_w, "rmat8+w"),
    ] {
        let mut delta = GraphDelta::new();
        let n = graph.n() as VertexId;
        if graph.is_weighted() {
            delta.insert_weighted(0, n - 1, 2.5).insert_weighted(n / 2, 0, 1.5);
        } else {
            delta.insert(0, n - 1).insert(n / 2, 0);
        }
        // Delete the first real edge plus an absent one (no-op replay).
        if let Some(&d0) = graph.out().neighbors(0).first() {
            delta.delete(0, d0);
        }
        delta.delete(n - 1, n - 1);
        let merged = merge_delta(&graph, &delta).unwrap();
        for k in [4usize, 16, 64] {
            let config = PpmConfig { k: Some(k), ..Default::default() };
            let parts = config.partitioner(graph.n());
            for threads in [1usize, 4] {
                let mut pool = ThreadPool::new(threads);
                let base = BinLayout::build_par(&graph, &parts, &mut pool);
                let patched =
                    base.apply_delta(&merged, &parts, &delta.dirty_parts(&parts), &mut pool);
                let fresh = BinLayout::build_par(&merged, &parts, &mut pool);
                assert!(patched == fresh, "{name} k={k} t={threads}: patched diverged");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Hot swap: generations, pools, racing checkouts
// ---------------------------------------------------------------------

#[test]
fn post_swap_queries_match_a_fresh_session_bitwise() {
    // threads = 1 makes gather order deterministic, so PageRank ranks
    // compare bit-for-bit across the swapped and the fresh session.
    let a = Arc::new(gen::rmat(9, Default::default(), false));
    let b = Arc::new(gen::erdos_renyi(700, 5600, 17));
    let config = PpmConfig { threads: 1, k: Some(16), ..Default::default() };
    let swapped = EngineSession::new(a.clone(), config.clone());
    let pre = Runner::on(&swapped).run(apps::PageRank::new(&a, 0.85));
    let stats = swapped.swap_graph(b.clone());
    assert_eq!(stats.source, PreprocessSource::Built);
    assert_eq!(swapped.generation(), 2);
    let fresh = EngineSession::new(b.clone(), config);
    assert!(*swapped.layout() == *fresh.layout(), "swapped layout diverged from fresh");
    let pr_a = Runner::on(&swapped).run(apps::PageRank::new(&b, 0.85));
    let pr_b = Runner::on(&fresh).run(apps::PageRank::new(&b, 0.85));
    assert_eq!(bits(&pr_a.output), bits(&pr_b.output), "post-swap PageRank diverged");
    assert_ne!(bits(&pr_a.output), bits(&pre.output), "swap visibly changed the answer");
    let bfs_a = Runner::on(&swapped).run(apps::Bfs::new(b.n(), 0));
    let bfs_b = Runner::on(&fresh).run(apps::Bfs::new(b.n(), 0));
    assert_eq!(bfs_a.output, bfs_b.output, "post-swap BFS diverged");
}

#[test]
fn post_swap_sssp_matches_fresh_at_four_threads() {
    // f32 min-combining is gather-order-independent, so distances agree
    // bit-for-bit even with nondeterministic t = 4 interleavings.
    let a = Arc::new(gen::with_uniform_weights(&gen::chain(300), 1.0, 4.0, 2));
    let b = Arc::new(gen::with_uniform_weights(&gen::erdos_renyi(500, 4000, 11), 1.0, 4.0, 5));
    let config = PpmConfig { threads: 4, k: Some(16), ..Default::default() };
    let swapped = EngineSession::new(a, config.clone());
    swapped.swap_graph(b.clone());
    let fresh = EngineSession::new(b.clone(), config);
    let d_a = Runner::on(&swapped).run(apps::Sssp::new(b.n(), 0));
    let d_b = Runner::on(&fresh).run(apps::Sssp::new(b.n(), 0));
    assert_eq!(bits(&d_a.output), bits(&d_b.output), "post-swap SSSP diverged at t=4");
}

#[test]
fn concurrent_checkouts_never_observe_a_torn_snapshot() {
    // Graphs with different (n, m): a torn graph/layout pair would break
    // the Σ meta.edges == m invariant the readers assert on every
    // checkout while the writer flips generations under them.
    let a = Arc::new(gen::erdos_renyi(300, 2400, 7));
    let b = Arc::new(gen::erdos_renyi(500, 1500, 8));
    let session = Arc::new(EngineSession::new(
        a.clone(),
        PpmConfig { threads: 1, k: Some(8), ..Default::default() },
    ));
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let session = Arc::clone(&session);
            let stop = &stop;
            s.spawn(move || {
                let mut last_gen = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let mut e = session.checkout();
                    let layout = e.layout().clone();
                    let graph = e.graph_arc().clone();
                    assert_eq!(layout.k(), e.parts().k(), "layout/partitioner torn");
                    let meta_edges: u64 =
                        (0..layout.k()).map(|p| layout.meta(p as u32).edges).sum();
                    assert_eq!(meta_edges, graph.m() as u64, "graph/layout torn");
                    let generation = e.generation();
                    assert!(generation >= last_gen, "generation went backwards");
                    last_gen = generation;
                    e.load_frontier(&[0]);
                    assert_eq!(e.frontier_size(), 1);
                }
            });
        }
        for i in 0..10 {
            let next: Arc<Graph> = if i % 2 == 0 { b.clone() } else { a.clone() };
            session.swap_graph(next);
        }
        stop.store(true, Ordering::Relaxed);
    });
    assert_eq!(session.generation(), 11, "ten swaps after the initial build");
}

// ---------------------------------------------------------------------
// Ingestion: sessions, persistence
// ---------------------------------------------------------------------

#[test]
fn post_ingest_queries_match_a_fresh_session_on_the_merged_graph() {
    let base =
        Arc::new(gen::with_uniform_weights(&gen::rmat(9, Default::default(), false), 1.0, 4.0, 7));
    let mut delta = GraphDelta::new();
    let n = base.n() as VertexId;
    delta.insert_weighted(0, n - 1, 1.25).insert_weighted(n - 1, 0, 0.75);
    if let Some(&d0) = base.out().neighbors(0).first() {
        delta.delete(0, d0);
    }
    let config = PpmConfig { threads: 1, k: Some(16), ..Default::default() };
    let patched = EngineSession::new(base.clone(), config.clone());
    let stats = patched.ingest(&delta).unwrap();
    assert_eq!(stats.source, PreprocessSource::Patched);
    assert_eq!(patched.generation(), 2);
    assert_eq!(patched.build_stats().source, PreprocessSource::Patched);
    let merged = Arc::new(merge_delta(&base, &delta).unwrap());
    assert_eq!(*patched.graph(), *merged, "session serves the canonical merged graph");
    let fresh = EngineSession::new(merged.clone(), config);
    assert!(*patched.layout() == *fresh.layout(), "patched layout diverged from fresh");
    let pr_a = Runner::on(&patched).run(apps::PageRank::new(&merged, 0.85));
    let pr_b = Runner::on(&fresh).run(apps::PageRank::new(&merged, 0.85));
    assert_eq!(bits(&pr_a.output), bits(&pr_b.output), "post-ingest PageRank diverged");
    assert_eq!(pr_a.preprocess, PreprocessSource::Patched, "reports name the delta path");
    let sp_a = Runner::on(&patched).run(apps::SsspParents::new(merged.n(), 0));
    let sp_b = Runner::on(&fresh).run(apps::SsspParents::new(merged.n(), 0));
    assert_eq!(bits(&sp_a.output.distance), bits(&sp_b.output.distance));
    assert_eq!(sp_a.output.parent, sp_b.output.parent, "post-ingest parents diverged");
}

#[test]
fn patched_layout_persists_with_a_fresh_digest() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("gpop_swap_persist_{}.layout", std::process::id()));
    let base = Arc::new(gen::erdos_renyi(400, 3200, 9));
    let config = PpmConfig { threads: 2, k: Some(8), ..Default::default() };
    let session = EngineSession::new(base.clone(), config.clone());
    let mut delta = GraphDelta::new();
    delta.insert(1, 399);
    if let Some(&d0) = base.out().neighbors(0).first() {
        delta.delete(0, d0);
    }
    session.ingest(&delta).unwrap();
    session.save(&path).unwrap();
    // Restoring against the merged graph works and is bit-identical...
    let merged = Arc::new(merge_delta(&base, &delta).unwrap());
    let warm = EngineSession::restore(merged.clone(), config.clone(), &path).unwrap();
    assert!(*warm.layout() == *session.layout(), "restored patched layout diverged");
    let rep = Runner::on(&warm).run(apps::Bfs::new(merged.n(), 0));
    assert!(rep.converged);
    // ...while the PRE-delta graph is refused: the save bound a fresh
    // digest of the mutated CSR.
    let err = EngineSession::restore(base, config, &path).expect_err("stale graph");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("different graph"), "got: {err}");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn ingest_amortizes_like_a_restore() {
    // The whole point: a small delta must not re-run the O(E) scan, and
    // queries on the patched session keep amortizing.
    let g = Arc::new(gen::erdos_renyi(500, 4000, 13));
    let session = EngineSession::new(g.clone(), PpmConfig { k: Some(16), ..Default::default() });
    let before = layout_builds();
    let mut delta = GraphDelta::new();
    delta.insert(3, 4).insert(400, 2);
    session.ingest(&delta).unwrap();
    for root in [0u32, 5, 17] {
        let rep = Runner::on(&session).run(apps::Bfs::new(g.n(), root));
        assert!(rep.converged);
        assert_eq!(rep.preprocess, PreprocessSource::Patched);
    }
    assert_eq!(layout_builds(), before, "ingest + queries never re-ran the O(E) scan");
}

#[test]
fn batch_runs_span_generations_cleanly() {
    // run_batch checks out ONE engine: it finishes its whole batch on
    // the generation it started on, even if a swap lands mid-batch.
    let a = Arc::new(gen::erdos_renyi(200, 1600, 3));
    let b = Arc::new(gen::chain(50));
    let session = EngineSession::new(a.clone(), PpmConfig { k: Some(8), ..Default::default() });
    let runner = Runner::on(&session);
    let reports = runner.run_batch((0..4u32).map(|r| apps::Bfs::new(a.n(), r)));
    assert_eq!(reports.len(), 4);
    session.swap_graph(b.clone());
    // A new batch sees the new graph (outputs sized to the new n).
    let reports = runner.run_batch((0..2u32).map(|r| apps::Bfs::new(b.n(), r)));
    assert!(reports.iter().all(|r| r.output.len() == b.n()));
}

#[test]
fn serve_loop_flips_generations_without_straddling_batches() {
    // Client threads hammer mixed BFS/PageRank while the main thread
    // lands swap_graph and ingest flips. Every accepted query is
    // answered, no batch observes two generations, generations are
    // monotone in batch order, and a saturated queue surfaces as typed
    // Overloaded backpressure — never a panic or a silent drop.
    let a = Arc::new(gen::erdos_renyi(300, 2400, 21));
    let b = Arc::new(gen::erdos_renyi(350, 2100, 22));
    let config = PpmConfig { threads: 1, k: Some(8), pool_cap: 2, ..Default::default() };
    let session = Arc::new(EngineSession::new(a.clone(), config.clone()));
    let sloop = ServeLoop::started(
        Arc::clone(&session),
        ServeConfig { queue_cap: 64, batch_max: 8, workers: 2 },
    );
    let handle = sloop.handle();
    let stop = AtomicBool::new(false);
    let mut delta = GraphDelta::new();
    delta.insert(0, 1);
    let (mut answered, total_shed) = std::thread::scope(|s| {
        let clients: Vec<_> = (0..4u32)
            .map(|c| {
                let handle = handle.clone();
                let stop = &stop;
                s.spawn(move || {
                    let mut oks: Vec<(u64, u64)> = Vec::new();
                    let mut shed = 0u64;
                    let mut i = 0u32;
                    while !stop.load(Ordering::Relaxed) {
                        let query = if (i + c) % 2 == 0 {
                            Query::Bfs { root: i % 100 }
                        } else {
                            Query::PageRank { damping: 0.85, max_iters: 3 }
                        };
                        i += 1;
                        match handle.submit(query) {
                            Ok(rx) => match rx.recv().expect("accepted query answered") {
                                Response::Ok(ok) => oks.push((ok.batch_seq, ok.generation)),
                                other => panic!("unexpected response: {other:?}"),
                            },
                            Err(SubmitError::Overloaded { capacity }) => {
                                assert_eq!(capacity, 64);
                                shed += 1;
                            }
                            Err(e) => panic!("unexpected submit error: {e:?}"),
                        }
                    }
                    (oks, shed)
                })
            })
            .collect();
        for flip in 0..4 {
            let next = if flip % 2 == 0 { b.clone() } else { a.clone() };
            sloop.swap_graph(next);
        }
        sloop.ingest(&delta).unwrap();
        stop.store(true, Ordering::Relaxed);
        let mut answered: Vec<(u64, u64)> = Vec::new();
        let mut total_shed = 0u64;
        for client in clients {
            let (oks, shed) = client.join().unwrap();
            answered.extend(oks);
            total_shed += shed;
        }
        (answered, total_shed)
    });
    assert_eq!(session.generation(), 6, "four swaps + one ingest from generation 1");
    assert!(!answered.is_empty(), "clients got answers while flips landed");
    let stats = handle.stats();
    assert_eq!(stats.rejected, total_shed, "every shed submit was counted");
    assert_eq!(stats.completed, answered.len() as u64, "every accepted submit was answered");
    assert_eq!(session.transient_checkouts(), 0, "serving never left the engine pool");
    // Sorted by (batch_seq, generation): members of one batch must agree
    // on the generation, and generations never regress across batches.
    answered.sort_unstable();
    for w in answered.windows(2) {
        if w[0].0 == w[1].0 {
            assert_eq!(w[0].1, w[1].1, "batch {} observed two generations", w[0].0);
        } else {
            assert!(w[1].1 >= w[0].1, "generation regressed at batch {}", w[1].0);
        }
    }
    // The session now sits on merge(a, delta): served answers must be
    // bit-identical to a fresh single-thread session on the merged graph.
    let merged = Arc::new(merge_delta(&a, &delta).unwrap());
    let served_bfs = match handle.submit_wait(Query::Bfs { root: 0 }) {
        Response::Ok(ok) => ok,
        other => panic!("unexpected response: {other:?}"),
    };
    let served_pr = match handle.submit_wait(Query::PageRank { damping: 0.85, max_iters: 3 }) {
        Response::Ok(ok) => ok,
        other => panic!("unexpected response: {other:?}"),
    };
    let fresh = EngineSession::new(merged.clone(), config);
    let fresh_bfs = Runner::on(&fresh).run(apps::Bfs::new(merged.n(), 0));
    assert_eq!(served_bfs.digest, output_digest_i32s(&fresh_bfs.output), "served BFS diverged");
    let fresh_pr = Runner::on(&fresh)
        .until(Convergence::L1Norm(PR_EPS).or_max_iters(3))
        .run(apps::PageRank::new(&merged, 0.85));
    assert_eq!(served_pr.digest, output_digest_f32s(&fresh_pr.output), "served PR diverged");
}
