//! Property tests for the parallel §4 pre-processing pipeline: parallel
//! builds must be *bit-identical* to serial ones (layout, CSR,
//! generators), sessions must amortize exactly one build, and a
//! panicking closure inside a pool region must propagate as a normal
//! panic (no hang, no use-after-free).

#[path = "prop_framework/mod.rs"]
mod prop_framework;

use std::sync::Arc;

use gpop::api::{EngineSession, Runner};
use gpop::apps;
use gpop::exec::ThreadPool;
use gpop::graph::{gen, Graph, GraphBuilder};
use gpop::partition::Partitioner;
use gpop::ppm::{layout_builds, BinLayout, PpmConfig};
use gpop::VertexId;
use prop_framework::property;

/// Thread counts exercised by the bit-identity properties: always the
/// full {2, 4, 8} spread (so every run covers multi-thread pools), plus
/// CI's `GPOP_TEST_THREADS` matrix value when it adds a new count
/// (t = 1 exercises the inline serial-pool edge).
fn test_threads() -> Vec<usize> {
    let mut ts = vec![2, 4, 8];
    if let Ok(t) = std::env::var("GPOP_TEST_THREADS") {
        if let Ok(t) = t.parse::<usize>() {
            if t >= 1 && !ts.contains(&t) {
                ts.push(t);
            }
        }
    }
    ts
}

fn weights_bits(g: &Graph) -> Option<Vec<u32>> {
    g.out().weights().map(|w| w.iter().map(|x| x.to_bits()).collect())
}

fn same_graph(a: &Graph, b: &Graph) -> Result<(), String> {
    prop_assert_eq!(a.n(), b.n(), "vertex count");
    prop_assert_eq!(a.out().offsets(), b.out().offsets(), "offsets");
    prop_assert_eq!(a.out().targets(), b.out().targets(), "targets");
    prop_assert_eq!(weights_bits(a), weights_bits(b), "weight bits");
    Ok(())
}

#[test]
fn prop_parallel_layout_build_is_bit_identical() {
    property("parallel BinLayout::build == serial", 25, |g| {
        let graph = g.graph(500, 8);
        let k = g.usize_in(1, graph.n().max(1));
        let parts = Partitioner::with_k(graph.n(), k);
        let serial = BinLayout::build(&graph, &parts);
        for t in test_threads() {
            let mut pool = ThreadPool::new(t);
            let par = BinLayout::build_par(&graph, &parts, &mut pool);
            prop_assert!(
                par == serial,
                "layout diverged: n={}, m={}, weighted={}, k={k}, t={t}",
                graph.n(),
                graph.m(),
                graph.is_weighted()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_csr_build_is_bit_identical() {
    property("GraphBuilder::build_with_pool == build", 25, |g| {
        let n = g.sized(1, 400);
        let m = g.usize_in(0, n * 8);
        let weighted = g.bool();
        let dedup = g.bool();
        let sym = g.bool();
        let loops = g.bool();
        let edges: Vec<(VertexId, VertexId, f32)> = (0..m)
            .map(|_| {
                (
                    g.rng.below(n as u64) as VertexId,
                    g.rng.below(n as u64) as VertexId,
                    0.5 + g.rng.next_f32() * 4.0,
                )
            })
            .collect();
        let make = || {
            let mut b = GraphBuilder::new().with_n(n);
            if dedup {
                b = b.dedup();
            }
            if sym {
                b = b.symmetrize();
            }
            if loops {
                b = b.drop_self_loops();
            }
            for &(s, d, w) in &edges {
                if weighted {
                    b.add_weighted(s, d, w);
                } else {
                    b.add(s, d);
                }
            }
            b
        };
        let serial = make().build();
        for t in test_threads() {
            let mut pool = ThreadPool::new(t);
            let par = make().build_with_pool(&mut pool);
            same_graph(&serial, &par).map_err(|e| {
                format!("t={t} weighted={weighted} dedup={dedup} sym={sym} loops={loops}: {e}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_generators_are_bit_identical() {
    property("rmat_par/erdos_renyi_par == serial", 6, |g| {
        let scale = g.usize_in(6, 9) as u32;
        let seed = g.rng.next_u64();
        for t in test_threads() {
            let mut pool = ThreadPool::new(t);
            let params = gen::RmatParams { seed, ..Default::default() };
            same_graph(
                &gen::rmat(scale, params, false),
                &gen::rmat_par(scale, params, false, &mut pool),
            )
            .map_err(|e| format!("rmat scale={scale} t={t}: {e}"))?;
            let n = 1usize << scale;
            same_graph(
                &gen::erdos_renyi(n, n * 4, seed),
                &gen::erdos_renyi_par(n, n * 4, seed, &mut pool),
            )
            .map_err(|e| format!("er n={n} t={t}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn session_amortizes_exactly_one_parallel_build() {
    let g = Arc::new(gen::rmat(10, Default::default(), false));
    let before = layout_builds();
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 4, k: Some(16), ..Default::default() });
    assert_eq!(layout_builds(), before + 1, "one parallel build, counted once");
    for root in [0u32, 7, 99] {
        let rep = Runner::on(&session).run(apps::Bfs::new(g.n(), root));
        assert!(rep.converged);
        assert!(
            rep.t_preprocess >= session.build_stats().t_layout,
            "queries surface the session's amortized pre-processing cost"
        );
    }
    assert_eq!(layout_builds(), before + 1, "queries never re-run pre-processing");
}

#[test]
fn parallel_and_serial_sessions_answer_identically() {
    // End-to-end: the same queries through a 1-thread and a 4-thread
    // session (parallel pre-processing AND parallel iterate) agree.
    let base = gen::rmat(9, Default::default(), false);
    let g = Arc::new(gen::with_uniform_weights(&base, 1.0, 4.0, 3));
    let cfg = |threads| PpmConfig { threads, k: Some(12), ..Default::default() };
    let s1 = EngineSession::new(g.clone(), cfg(1));
    let s4 = EngineSession::new(g.clone(), cfg(4));
    let d1 = Runner::on(&s1).run(apps::Sssp::new(g.n(), 0));
    let d4 = Runner::on(&s4).run(apps::Sssp::new(g.n(), 0));
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&d1.output), bits(&d4.output), "SSSP distances must not depend on threads");
}

#[test]
#[should_panic(expected = "preprocess region boom")]
fn panicking_region_closure_propagates_not_hangs() {
    let mut pool = ThreadPool::new(4);
    // Regression: pre-fix this either deadlocked the caller (worker
    // never decremented `remaining`) or freed the stack closure while
    // workers still held a pointer to it.
    pool.for_each_dynamic(64, 1, |i, _tid| {
        if i == 17 {
            panic!("preprocess region boom");
        }
    });
}

#[test]
fn pool_survives_a_panicking_build_closure() {
    let mut pool = ThreadPool::new(4);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.map_parts(32, |i| {
            if i == 5 {
                panic!("boom in row build");
            }
            i * 2
        })
    }));
    assert!(r.is_err(), "panic must propagate out of the region");
    // The team is intact: the very next parallel build works.
    let g = gen::chain(100);
    let parts = Partitioner::with_k(g.n(), 8);
    let serial = BinLayout::build(&g, &parts);
    let par = BinLayout::build_par(&g, &parts, &mut pool);
    assert!(par == serial, "pool must stay consistent after a panic");
}
