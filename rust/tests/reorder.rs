//! Vertex reordering (PR 10) acceptance: a locality permutation must be
//! **caller-invisible**. Every query against a reordered session comes
//! back in original vertex ids, bit-identical to the same query against
//! an unreordered session — across all three strategies, the k × threads
//! matrix, and the save/load artifact path. The permutation artifact
//! itself is versioned + checksummed: corrupt bytes, truncation and
//! stale graph pairings are refused as `InvalidData`, never half-loaded.
//!
//! The payoff side is checked with the in-repo cache simulator: on the
//! skewed RMAT at least one strategy must cut the simulated pull-model
//! misses (the vertex-order-sensitive access pattern) vs. the baseline
//! numbering.

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{Bfs, LabelProp, PageRank, SsspParents};
use gpop::cachesim::model::{self, Framework};
use gpop::cachesim::CacheConfig;
use gpop::graph::{gen, Graph};
use gpop::ppm::PpmConfig;
use gpop::reorder::{self, Strategy};
use std::path::PathBuf;

/// Weighted RMAT: skewed degrees (the regime reordering exists for),
/// weights so SSSP-with-parents runs too.
fn graph() -> Graph {
    gen::with_uniform_weights(&gen::rmat(10, Default::default(), true), 1.0, 4.0, 7)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gpop_reorder_test_{}_{name}", std::process::id()))
}

fn pagerank(session: &EngineSession, iters: usize) -> Vec<f32> {
    Runner::on(session)
        .until(Convergence::MaxIters(iters))
        .run(PageRank::new(&session.graph(), 0.85))
        .output
}

fn bfs(session: &EngineSession, root: u32) -> Vec<i32> {
    Runner::on(session).run(Bfs::new(session.graph().n(), root)).output
}

fn sssp_parents(session: &EngineSession, root: u32) -> (Vec<f32>, Vec<u32>) {
    let out = Runner::on(session).run(SsspParents::new(session.graph().n(), root)).output;
    (out.distance, out.parent)
}

fn cc(session: &EngineSession) -> Vec<u32> {
    Runner::on(session).run(LabelProp::new(session.graph().n())).output
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The tentpole contract: reordering is invisible at the result surface.
/// PageRank / BFS / SSSP-parents / label propagation on a reordered
/// session must equal the unreordered run **bit for bit** (original ids,
/// same float bits) for every strategy × k × threads combination.
#[test]
fn reordered_results_bit_identical_across_strategies_k_threads() {
    let g = graph();
    for k in [4usize, 16, 64] {
        for threads in [1usize, 4] {
            let config = PpmConfig { k: Some(k), threads, ..Default::default() };
            let base = EngineSession::new(g.clone(), config.clone());
            let want_pr = pagerank(&base, 5);
            let want_bfs = bfs(&base, 0);
            let (want_dist, want_par) = sssp_parents(&base, 0);
            let want_cc = cc(&base);
            for strategy in Strategy::ALL {
                let session = EngineSession::reordered(g.clone(), strategy, config.clone());
                let ctx = format!("strategy={strategy} k={k} threads={threads}");
                assert!(
                    session.permutation().is_some(),
                    "{ctx}: reordered session must carry its permutation"
                );
                assert!(bits_eq(&want_pr, &pagerank(&session, 5)), "pagerank differs: {ctx}");
                assert_eq!(want_bfs, bfs(&session, 0), "bfs differs: {ctx}");
                let (dist, par) = sssp_parents(&session, 0);
                assert!(bits_eq(&want_dist, &dist), "sssp distance differs: {ctx}");
                assert_eq!(want_par, par, "sssp parent differs: {ctx}");
                assert_eq!(want_cc, cc(&session), "cc differs: {ctx}");
            }
        }
    }
}

/// perm ∘ inv == id in both directions, and the forward map is a true
/// permutation (every new id hit exactly once).
#[test]
fn permutation_roundtrips_to_identity() {
    let g = gen::rmat(8, Default::default(), false);
    for strategy in Strategy::ALL {
        let (_rg, perm) = reorder::reorder_graph(&g, strategy, None);
        assert_eq!(perm.n(), g.n(), "{strategy}: permutation covers the graph");
        let mut seen = vec![false; g.n()];
        for v in 0..g.n() as u32 {
            let new = perm.new_id(v);
            assert_eq!(perm.old_id(new), v, "{strategy}: old∘new != id at {v}");
            assert_eq!(perm.new_id(perm.old_id(v)), v, "{strategy}: new∘old != id at {v}");
            assert!(!seen[new as usize], "{strategy}: new id {new} assigned twice");
            seen[new as usize] = true;
        }
    }
}

/// The artifact path: a saved permutation restores against the graph it
/// was written for (and the restored session answers in original ids),
/// while corruption, truncation and stale graph pairings are all refused
/// as `InvalidData`.
#[test]
fn permutation_artifacts_validate_or_refuse() {
    let g = graph();
    let (rg, perm) = reorder::reorder_graph(&g, Strategy::Degree, None);
    let path = tmp("perm.bin");
    reorder::save_permutation(&path, &perm, &g, &rg).expect("save permutation");

    // Round-trip: loads against the reordered graph, serves original ids.
    let loaded = reorder::load_permutation(&path, &rg).expect("load permutation");
    assert_eq!(loaded.n(), perm.n());
    let config = PpmConfig { k: Some(8), threads: 2, ..Default::default() };
    let base = EngineSession::new(g.clone(), config.clone());
    let session =
        EngineSession::with_permutation(rg.clone(), loaded, config).expect("restore session");
    assert_eq!(bfs(&base, 0), bfs(&session, 0), "restored session must serve original ids");

    // Stale: the artifact binds the reordered graph's digest — loading it
    // against a *different* graph (here: the original) must be refused.
    let stale = reorder::load_permutation(&path, &g).expect_err("stale pairing must fail");
    assert_eq!(stale.kind(), std::io::ErrorKind::InvalidData, "stale: {stale}");

    let bytes = std::fs::read(&path).expect("read artifact");

    // Corrupt: flip one byte in the permutation body.
    let corrupt_path = tmp("perm_corrupt.bin");
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x40;
    std::fs::write(&corrupt_path, &corrupt).expect("write corrupt artifact");
    let err = reorder::load_permutation(&corrupt_path, &rg).expect_err("corrupt must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "corrupt: {err}");

    // Truncated: drop the tail.
    let trunc_path = tmp("perm_trunc.bin");
    std::fs::write(&trunc_path, &bytes[..bytes.len() - 9]).expect("write truncated artifact");
    let err = reorder::load_permutation(&trunc_path, &rg).expect_err("truncated must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "truncated: {err}");

    // Bad magic.
    let magic_path = tmp("perm_magic.bin");
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    std::fs::write(&magic_path, &bad).expect("write bad-magic artifact");
    let err = reorder::load_permutation(&magic_path, &rg).expect_err("bad magic must fail");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "bad magic: {err}");

    for p in [path, corrupt_path, trunc_path, magic_path] {
        let _ = std::fs::remove_file(p);
    }
}

/// The locality payoff, measured with the in-repo cache simulator: on
/// the skewed RMAT under cache pressure, the best strategy must reduce
/// the pull-model (Ligra-style `vdata[u]` read per edge) miss count —
/// the directly vertex-order-sensitive pattern — vs. the generator's
/// native numbering. (The GPOP trace itself is partition-blocked and
/// largely order-insensitive by design, so it is not asserted on.)
#[test]
fn degree_ordering_cuts_pull_misses_on_skewed_rmat() {
    let g = gen::rmat(12, Default::default(), false);
    // 4 KB simulated cache against 16 KB of vertex data: the pressure
    // regime where packing the reference mass into few lines pays.
    let cache = CacheConfig { size_bytes: 4 * 1024, ..Default::default() };
    let history = model::pagerank_history(&g, 2);
    let baseline = model::simulate(&g, Framework::Ligra, &history, cache, 1);
    let best = Strategy::ALL
        .iter()
        .map(|&s| {
            let (rg, _) = reorder::reorder_graph(&g, s, None);
            let h = model::pagerank_history(&rg, 2);
            let misses = model::simulate(&rg, Framework::Ligra, &h, cache, 1);
            println!("strategy {s}: {misses} pull misses (baseline {baseline})");
            misses
        })
        .min()
        .unwrap();
    assert!(
        best < baseline,
        "no strategy improved pull locality: best {best} vs baseline {baseline}"
    );
}
