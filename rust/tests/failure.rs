//! Failure-injection tests: malformed inputs, degenerate graphs and
//! misconfigurations must fail loudly (or degrade gracefully), never
//! corrupt results.

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{self, bfs};
use gpop::coordinator::{self, GraphSpec};
use gpop::graph::{builder::graph_from_edges, gen, io};
use gpop::ppm::{Engine, PpmConfig};
use gpop::runtime::Manifest;
use std::sync::Arc;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("gpop_fail_{}_{name}", std::process::id()));
    p
}

// ------------------------------------------------------------ inputs

#[test]
fn malformed_edge_list_rejected() {
    for body in ["0 x\n", "0\n", "9999999999999999999 1\n"] {
        let p = tmp("bad.el");
        std::fs::write(&p, body).unwrap();
        assert!(io::read_edge_list(&p).is_err(), "accepted {body:?}");
        std::fs::remove_file(&p).unwrap();
    }
}

#[test]
fn truncated_binary_rejected() {
    let g = gen::chain(10);
    let p = tmp("trunc.bin");
    io::write_binary(&g, &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    assert!(io::read_binary(&p).is_err());
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn malformed_manifest_rejected() {
    for body in ["", "[]", "{\"k\": 8}", "{\"k\": \"eight\", \"q\": 1}"] {
        assert!(Manifest::parse(body).is_err(), "accepted {body:?}");
    }
}

#[test]
fn cli_bad_inputs_surface_errors() {
    let cases: Vec<Vec<&str>> = vec![
        vec!["run", "--app", "bfs"],                       // no graph
        vec!["run", "--app", "nope", "--graph", "chain:4"], // unknown app
        vec!["run", "--app", "bfs", "--graph", "rmat"],     // bad spec
        vec!["run", "--app", "bfs", "--graph", "chain:4", "--threads", "zero"],
        vec!["run", "--app", "bfs", "--graph", "chain:4", "--mode", "fastest"],
        vec!["frobnicate"],                                 // unknown command
        vec!["gen", "--graph", "chain:4"],                  // no --out
    ];
    for argv in cases {
        let r = coordinator::dispatch(argv.iter().map(|s| s.to_string()).collect());
        assert!(r.is_err(), "should fail: {argv:?}");
    }
}

#[test]
fn spec_file_missing_errors() {
    let spec = GraphSpec::parse("file:/definitely/not/here.bin").unwrap();
    assert!(spec.build().is_err());
}

// -------------------------------------------------- degenerate graphs

#[test]
fn empty_graph_runs_everything() {
    let g = graph_from_edges(0, &[]);
    let session = EngineSession::new(g, PpmConfig::default());
    let pr = Runner::on(&session)
        .until(Convergence::MaxIters(3))
        .run(apps::PageRank::new(&session.graph(), 0.85));
    assert!(pr.output.is_empty());
    let cc = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(10))
        .run(apps::LabelProp::new(0));
    assert!(cc.output.is_empty());
}

#[test]
fn single_vertex_no_edges() {
    let g = graph_from_edges(1, &[]);
    let session = EngineSession::new(g, PpmConfig::default());
    let res = Runner::on(&session).run(apps::Bfs::new(1, 0));
    assert_eq!(res.output, vec![0]);
    assert!(res.converged);
    let pr = Runner::on(&session)
        .until(Convergence::MaxIters(2))
        .run(apps::PageRank::new(&session.graph(), 0.85));
    // Isolated vertex: rank = teleport mass only.
    assert!((pr.output[0] - 0.15).abs() < 1e-6);
}

#[test]
fn self_loops_and_parallel_edges() {
    let g = graph_from_edges(3, &[(0, 0), (0, 1), (0, 1), (1, 2), (2, 2)]);
    let session = EngineSession::new(g, PpmConfig { k: Some(3), ..Default::default() });
    let res = Runner::on(&session).run(apps::Bfs::new(3, 0));
    assert!(res.output.iter().all(|&p| p >= 0), "all reachable: {:?}", res.output);
    // PageRank with self loops must still be bounded.
    let pr = Runner::on(&session)
        .until(Convergence::MaxIters(10))
        .run(apps::PageRank::new(&session.graph(), 0.85));
    let mass: f64 = pr.output.iter().map(|&x| x as f64).sum();
    assert!(mass <= 1.0 + 1e-5 && mass > 0.0);
}

#[test]
fn star_hub_extreme_degree() {
    // One vertex with n-1 out-edges: stresses single-partition bins.
    let n = 5000u32;
    let edges: Vec<(u32, u32)> = (1..n).map(|v| (0, v)).collect();
    let g = graph_from_edges(n as usize, &edges);
    let session =
        EngineSession::new(g, PpmConfig { threads: 2, k: Some(8), ..Default::default() });
    let res = Runner::on(&session).run(apps::Bfs::new(n as usize, 0));
    assert_eq!(bfs::n_reached(&res.output), n as usize);
    assert_eq!(res.n_iters(), 2); // root scatter + empty check
}

#[test]
fn unreachable_root_degenerate_frontier() {
    let g = graph_from_edges(10, &[(0, 1)]);
    let session = EngineSession::new(g, PpmConfig::default());
    let res = Runner::on(&session).run(apps::Bfs::new(10, 9)); // deg(9) = 0
    assert_eq!(bfs::n_reached(&res.output), 1);
    assert!(res.converged);
}

// ---------------------------------------------------- configurations

#[test]
fn k_exceeding_vertices_is_clamped() {
    let g = gen::chain(5);
    let eng = Engine::new(g, PpmConfig { k: Some(100), ..Default::default() });
    assert!(eng.parts().k() <= 5);
}

#[test]
fn extreme_bw_ratios_still_correct() {
    let g = Arc::new(gen::rmat(9, Default::default(), false));
    let baseline = {
        let session = EngineSession::new(g.clone(), PpmConfig::default());
        let res = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
        bfs::n_reached(&res.output)
    };
    for ratio in [0.01, 100.0] {
        let session = EngineSession::new(
            g.clone(),
            PpmConfig { threads: 2, bw_ratio: ratio, ..Default::default() },
        );
        let res = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
        assert_eq!(bfs::n_reached(&res.output), baseline, "ratio {ratio}");
    }
}

#[test]
fn oversubscribed_threads_work() {
    // 8 threads on a 1-hw-thread container: correctness must hold.
    let g = Arc::new(gen::rmat(10, Default::default(), false));
    let session =
        EngineSession::new(g.clone(), PpmConfig { threads: 8, ..Default::default() });
    let res = Runner::on(&session).run(apps::Bfs::new(g.n(), 0));
    let want = gpop::baselines::serial::bfs_levels(&g, 0);
    assert_eq!(bfs::levels(&res.output, 0), want);
}

#[test]
#[should_panic]
fn zero_threads_rejected() {
    let g = gen::chain(4);
    let _ = Engine::new(g, PpmConfig { threads: 0, ..Default::default() });
}

#[test]
#[should_panic]
fn zero_threads_rejected_by_session() {
    let g = gen::chain(4);
    let _ = EngineSession::new(g, PpmConfig { threads: 0, ..Default::default() });
}

#[test]
#[should_panic]
fn pjrt_blocks_shape_mismatch_panics() {
    let g = gen::chain(5); // n=5 != k*q=4
    let _ = gpop::runtime::pjrt::graph_to_blocks(&g, 2, 2);
}
