//! Tests for the disjointness sanitizer (`--features sanitize`): the
//! seeded-race negatives prove the checker actually fires with both
//! writers identified, and the clean cases pin down what the engine's
//! legal access patterns look like to the claim table (disjoint indices
//! within an epoch, same-index handoffs across region barriers,
//! same-thread rewrites).
//!
//! Without the feature this file compiles to an empty test binary (see
//! the `[[test]]` entry in Cargo.toml).
//!
//! Write epochs are keyed per pool (PR 9), so a pool region in a
//! concurrently running test can no longer advance *our* epoch between
//! a seeded race's two claims and mask the overlap. The seeded
//! negatives therefore fire deterministically on the first attempt —
//! the bounded-retry workaround this file used to carry is gone — and
//! `concurrent_pool_epoch_advance_cannot_mask_an_overlap` pins the fix.

#![cfg(feature = "sanitize")]

use std::panic::{catch_unwind, AssertUnwindSafe};

use gpop::exec::{SharedSlice, ThreadPool};
use gpop::ppm::shared::SharedCells;

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        String::new()
    }
}

/// Run `race` (which seeds a same-epoch overlapping write) and return
/// the sanitizer's diagnostic. One attempt: per-pool epochs make the
/// catch deterministic.
fn catch_seeded_race(race: impl FnOnce()) -> String {
    match catch_unwind(AssertUnwindSafe(race)) {
        Err(payload) => panic_message(payload.as_ref()),
        Ok(()) => panic!("sanitizer failed to catch a seeded overlapping write"),
    }
}

#[test]
fn seeded_overlapping_write_is_caught_with_both_threads_named() {
    let mut pool = ThreadPool::new(2);
    let msg = catch_seeded_race(|| {
        let mut buf = vec![0u32; 4];
        let shared = SharedSlice::new(&mut buf);
        pool.run(|tid| {
            // SAFETY: deliberately NOT disjoint — every team member
            // writes index 0 so the sanitizer must abort. (This is the
            // bug the engine's partition-ownership schedule prevents.)
            unsafe { shared.write(0, tid as u32) };
        });
    });
    assert!(
        msg.contains("sanitize: overlapping write claim on SharedSlice[0]"),
        "diagnostic must name the region and index: {msg}"
    );
    assert!(msg.contains("gpop-worker-1"), "diagnostic must identify the worker thread: {msg}");
    if let Some(name) = std::thread::current().name() {
        assert!(msg.contains(name), "diagnostic must identify the caller thread too: {msg}");
    }
    assert!(msg.contains("epoch"), "diagnostic must name the epoch: {msg}");
    assert!(msg.contains("pool"), "diagnostic must name the claiming pool: {msg}");
}

#[test]
fn seeded_shared_cells_overlap_is_caught() {
    let mut pool = ThreadPool::new(2);
    let msg = catch_seeded_race(|| {
        let cells = SharedCells::from_vec(vec![0u64; 2]);
        pool.run(|_tid| {
            // SAFETY: deliberately overlapping, to trip the sanitizer.
            unsafe { *cells.get_mut(1) += 1 };
        });
    });
    assert!(
        msg.contains("overlapping write claim on SharedCells[1]"),
        "diagnostic must name the region and index: {msg}"
    );
}

/// The PR 8 false negative, now a hard regression test: another pool
/// hammering region barriers *while* our region is mid-flight must not
/// advance our epoch and legalize a two-writer overlap. With the old
/// process-global epoch this masked the race nondeterministically;
/// with per-pool epochs the overlap is caught every time, even under a
/// worst-case interleaving seeded right here.
#[test]
fn concurrent_pool_epoch_advance_cannot_mask_an_overlap() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let noisy = Arc::new(AtomicBool::new(true));
    let noise = std::thread::spawn({
        let stop = Arc::clone(&noisy);
        move || {
            // A separate pool advancing its own epoch as fast as it can.
            let mut other = ThreadPool::new(1);
            while stop.load(Ordering::Relaxed) {
                other.run(|_| {});
            }
        }
    });

    let mut pool = ThreadPool::new(2);
    for _ in 0..20 {
        let msg = catch_seeded_race(|| {
            let mut buf = vec![0u32; 2];
            let shared = SharedSlice::new(&mut buf);
            pool.run(|tid| {
                if tid == 1 {
                    // Give the noisy pool time to cycle many regions
                    // between the two conflicting claims.
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                // SAFETY: deliberately overlapping, to trip the sanitizer.
                unsafe { shared.write(0, tid as u32) };
            });
        });
        assert!(
            msg.contains("overlapping write claim on SharedSlice[0]"),
            "a concurrent pool's barriers masked the overlap: {msg}"
        );
    }
    noisy.store(false, Ordering::Relaxed);
    noise.join().unwrap();
}

#[test]
fn disjoint_writes_stay_clean_across_many_regions() {
    let mut pool = ThreadPool::new(4);
    let mut buf = vec![0u32; 64];
    let shared = SharedSlice::new(&mut buf);
    for _ in 0..8 {
        pool.run(|tid| {
            for i in (tid..64).step_by(4) {
                // SAFETY: indices are disjoint across the team.
                unsafe { shared.write(i, i as u32) };
            }
        });
    }
    drop(shared);
    assert!(buf.iter().enumerate().all(|(i, &x)| x == i as u32));
}

#[test]
fn same_index_handoff_across_region_barrier_is_clean() {
    let mut pool = ThreadPool::new(2);
    let mut buf = vec![0u32; 1];
    let shared = SharedSlice::new(&mut buf);
    pool.run(|tid| {
        if tid == 0 {
            // SAFETY: only tid 0 writes in this region.
            unsafe { shared.write(0, 1) };
        }
    });
    pool.run(|tid| {
        if tid == 1 {
            // SAFETY: only tid 1 writes in this region; the barrier
            // between regions is what legalizes the handoff (each
            // region is a fresh epoch).
            unsafe { shared.write(0, 2) };
        }
    });
    drop(shared);
    assert_eq!(buf[0], 2);
}

/// Two pools writing the same region in back-to-back (non-overlapping)
/// regions is a legal handoff, not a conflict: cross-pool claims never
/// share an epoch, and the pools' own barriers order the writes.
#[test]
fn sequential_regions_of_different_pools_are_clean() {
    let mut a = ThreadPool::new(2);
    let mut b = ThreadPool::new(2);
    let mut buf = vec![0u32; 8];
    let shared = SharedSlice::new(&mut buf);
    a.run(|tid| {
        for i in (tid..8).step_by(2) {
            // SAFETY: disjoint across pool a's team.
            unsafe { shared.write(i, 1) };
        }
    });
    b.run(|tid| {
        for i in (tid..8).step_by(2) {
            // SAFETY: disjoint across pool b's team; pool a's region
            // fully finished (its run() returned) before this one.
            unsafe { shared.write(i, 2) };
        }
    });
    drop(shared);
    assert!(buf.iter().all(|&x| x == 2));
}

#[test]
fn same_thread_may_rewrite_within_an_epoch() {
    let mut buf = vec![0u32; 2];
    let shared = SharedSlice::new(&mut buf);
    // SAFETY: single thread, exclusive use.
    unsafe { shared.write(0, 1) };
    // SAFETY: same thread again — not a cross-thread conflict.
    unsafe { shared.write(0, 2) };
    drop(shared);
    assert_eq!(buf[0], 2);
}

#[test]
fn map_parts_is_clean_under_sanitize() {
    let mut pool = ThreadPool::new(4);
    let out = pool.map_parts(512, |i| i as u32 * 3);
    assert!(out.iter().enumerate().all(|(i, &x)| x == i as u32 * 3));
}
