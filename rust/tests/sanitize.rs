//! Tests for the disjointness sanitizer (`--features sanitize`): the
//! seeded-race negatives prove the checker actually fires with both
//! writers identified, and the clean cases pin down what the engine's
//! legal access patterns look like to the claim table (disjoint indices
//! within an epoch, same-index handoffs across region barriers,
//! same-thread rewrites).
//!
//! Without the feature this file compiles to an empty test binary (see
//! the `[[test]]` entry in Cargo.toml).
//!
//! The write epoch is process-global, so a pool region in a
//! concurrently running test can advance it between a seeded race's two
//! claims and mask the overlap — a documented false negative, never a
//! false positive. The negative tests retry a bounded number of times;
//! the clean tests are deterministic.

#![cfg(feature = "sanitize")]

use std::panic::{catch_unwind, AssertUnwindSafe};

use gpop::exec::{SharedSlice, ThreadPool};
use gpop::ppm::shared::SharedCells;

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else {
        String::new()
    }
}

/// Run `race` (which seeds a same-epoch overlapping write) until the
/// sanitizer catches it, retrying past cross-test epoch interleavings.
fn catch_seeded_race(attempts: usize, mut race: impl FnMut()) -> String {
    for _ in 0..attempts {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(&mut race)) {
            return panic_message(payload.as_ref());
        }
    }
    panic!("sanitizer failed to catch a seeded overlapping write in {attempts} attempts");
}

#[test]
fn seeded_overlapping_write_is_caught_with_both_threads_named() {
    let mut pool = ThreadPool::new(2);
    let msg = catch_seeded_race(20, || {
        let mut buf = vec![0u32; 4];
        let shared = SharedSlice::new(&mut buf);
        pool.run(|tid| {
            // SAFETY: deliberately NOT disjoint — every team member
            // writes index 0 so the sanitizer must abort. (This is the
            // bug the engine's partition-ownership schedule prevents.)
            unsafe { shared.write(0, tid as u32) };
        });
    });
    assert!(
        msg.contains("sanitize: overlapping write claim on SharedSlice[0]"),
        "diagnostic must name the region and index: {msg}"
    );
    assert!(msg.contains("gpop-worker-1"), "diagnostic must identify the worker thread: {msg}");
    if let Some(name) = std::thread::current().name() {
        assert!(msg.contains(name), "diagnostic must identify the caller thread too: {msg}");
    }
    assert!(msg.contains("epoch"), "diagnostic must name the epoch: {msg}");
}

#[test]
fn seeded_shared_cells_overlap_is_caught() {
    let mut pool = ThreadPool::new(2);
    let msg = catch_seeded_race(20, || {
        let cells = SharedCells::from_vec(vec![0u64; 2]);
        pool.run(|_tid| {
            // SAFETY: deliberately overlapping, to trip the sanitizer.
            unsafe { *cells.get_mut(1) += 1 };
        });
    });
    assert!(
        msg.contains("overlapping write claim on SharedCells[1]"),
        "diagnostic must name the region and index: {msg}"
    );
}

#[test]
fn disjoint_writes_stay_clean_across_many_regions() {
    let mut pool = ThreadPool::new(4);
    let mut buf = vec![0u32; 64];
    let shared = SharedSlice::new(&mut buf);
    for _ in 0..8 {
        pool.run(|tid| {
            for i in (tid..64).step_by(4) {
                // SAFETY: indices are disjoint across the team.
                unsafe { shared.write(i, i as u32) };
            }
        });
    }
    drop(shared);
    assert!(buf.iter().enumerate().all(|(i, &x)| x == i as u32));
}

#[test]
fn same_index_handoff_across_region_barrier_is_clean() {
    let mut pool = ThreadPool::new(2);
    let mut buf = vec![0u32; 1];
    let shared = SharedSlice::new(&mut buf);
    pool.run(|tid| {
        if tid == 0 {
            // SAFETY: only tid 0 writes in this region.
            unsafe { shared.write(0, 1) };
        }
    });
    pool.run(|tid| {
        if tid == 1 {
            // SAFETY: only tid 1 writes in this region; the barrier
            // between regions is what legalizes the handoff (each
            // region is a fresh epoch).
            unsafe { shared.write(0, 2) };
        }
    });
    drop(shared);
    assert_eq!(buf[0], 2);
}

#[test]
fn same_thread_may_rewrite_within_an_epoch() {
    let mut buf = vec![0u32; 2];
    let shared = SharedSlice::new(&mut buf);
    // SAFETY: single thread, exclusive use.
    unsafe { shared.write(0, 1) };
    // SAFETY: same thread again — not a cross-thread conflict.
    unsafe { shared.write(0, 2) };
    drop(shared);
    assert_eq!(buf[0], 2);
}

#[test]
fn map_parts_is_clean_under_sanitize() {
    let mut pool = ThreadPool::new(4);
    let out = pool.map_parts(512, |i| i as u32 * 3);
    assert!(out.iter().enumerate().all(|(i, &x)| x == i as u32 * 3));
}
