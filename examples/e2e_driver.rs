//! End-to-end driver: the full system on a real workload.
//!
//! Exercises every layer on a Graph500-style RMAT workload (default
//! scale 18: 262K vertices, ~4.2M edges — pass a scale argument to go
//! bigger): graph generation → ONE `EngineSession` (partitioning/PNG
//! pre-processing paid once) → all five paper applications through the
//! `Runner` → per-iteration logs → cross-checks against serial
//! references → throughput/metrics report. This is the run recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example e2e_driver [scale] [threads]`

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{bfs, cc, Bfs, LabelProp, Nibble, PageRank, Sssp};
use gpop::baselines::serial;
use gpop::exec::ThreadPool;
use gpop::graph::gen;
use gpop::ppm::PpmConfig;
use gpop::util::fmt;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let scale: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(18);
    let threads: usize = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(ThreadPool::available_parallelism);

    println!("=== GPOP end-to-end driver ===");
    println!("workload: rmat{scale} (Graph500 params, degree 16), {threads} threads\n");

    let t0 = Instant::now();
    let graph = std::sync::Arc::new(gen::rmat(scale, Default::default(), false));
    println!(
        "[gen]  {} vertices, {} edges in {}",
        fmt::si(graph.n() as f64),
        fmt::si(graph.m() as f64),
        fmt::secs(t0.elapsed().as_secs_f64())
    );

    let t1 = Instant::now();
    let config = PpmConfig { threads, ..Default::default() };
    let session = EngineSession::new(graph.clone(), config);
    println!(
        "[prep] k = {} partitions (q = {}) in {} — bins + PNG + active lists",
        session.parts().k(),
        session.parts().q(),
        fmt::secs(t1.elapsed().as_secs_f64())
    );
    let runner = Runner::on(&session);

    // ---------------- PageRank ----------------
    let t = Instant::now();
    let pr = Runner::on(&session)
        .until(Convergence::MaxIters(10))
        .run(PageRank::new(&graph, 0.85));
    let pr_time = t.elapsed().as_secs_f64();
    let edges10 = graph.m() as f64 * 10.0;
    println!(
        "\n[pagerank] 10 iters in {} — {} edges/s ({} DC / {} SC scatters)",
        fmt::secs(pr_time),
        fmt::si(edges10 / pr_time),
        pr.dc_parts(),
        pr.sc_parts(),
    );
    let mass: f64 = pr.output.iter().map(|&x| x as f64).sum();
    println!("[pagerank] rank mass = {mass:.4} (≤ 1, dangling dropped)");

    // ---------------- BFS ----------------
    let t = Instant::now();
    let bfs_rep = runner.run(Bfs::new(graph.n(), 0));
    let bfs_time = t.elapsed().as_secs_f64();
    let bfs_reached = bfs::n_reached(&bfs_rep.output);
    let serial_reach = serial::bfs_levels(&graph, 0).iter().filter(|&&l| l >= 0).count();
    assert_eq!(bfs_reached, serial_reach, "BFS reachability mismatch vs serial");
    println!(
        "\n[bfs] {} iters, reached {} in {} — {} edges/s (verified vs serial)",
        bfs_rep.n_iters(),
        fmt::si(bfs_reached as f64),
        fmt::secs(bfs_time),
        fmt::si(bfs_rep.total_messages() as f64 / bfs_time)
    );

    // ---------------- Connected components ----------------
    let t = Instant::now();
    let cc_rep = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(10_000))
        .run(LabelProp::new(graph.n()));
    let cc_time = t.elapsed().as_secs_f64();
    println!(
        "\n[cc] {} iters, {} label classes in {}",
        cc_rep.n_iters(),
        fmt::si(cc::n_components(&cc_rep.output) as f64),
        fmt::secs(cc_time)
    );

    // ---------------- SSSP (weighted) ----------------
    let t = Instant::now();
    let wg = gen::with_uniform_weights(&graph, 1.0, 4.0, 7);
    let wsession = EngineSession::new(wg, PpmConfig { threads, ..Default::default() });
    let prep_w = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let sssp = Runner::on(&wsession).run(Sssp::new(graph.n(), 0));
    let sssp_time = t.elapsed().as_secs_f64();
    let reached = sssp.output.iter().filter(|d| d.is_finite()).count();
    assert_eq!(reached, serial_reach, "SSSP reachability mismatch");
    println!(
        "\n[sssp] {} iters, reached {} in {} (weighted prep {})",
        sssp.n_iters(),
        fmt::si(reached as f64),
        fmt::secs(sssp_time),
        fmt::secs(prep_w)
    );

    // ---------------- Nibble (local clustering) ----------------
    // Seed a *low-degree* vertex (local clustering's use case — hub
    // seeds flood by design) with a threshold that truncates quickly.
    let t = Instant::now();
    let seed = (0..graph.n() as u32)
        .find(|&v| (1..=4).contains(&graph.out_degree(v)))
        .unwrap_or(0);
    let nib = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(200))
        .run(Nibble::new(&graph, 1e-3, &[seed]));
    let nib_time = t.elapsed().as_secs_f64();
    let o_e_cost = nib.n_iters() as u64 * graph.m() as u64;
    println!(
        "\n[nibble] seed {seed} (deg {}): support {} / {} vertices in {} — {} messages \
         vs {} for an O(E)/iter engine",
        graph.out_degree(seed),
        fmt::si(nib.output.support as f64),
        fmt::si(graph.n() as f64),
        fmt::secs(nib_time),
        fmt::si(nib.total_messages() as f64),
        fmt::si(o_e_cost as f64)
    );
    assert!(
        nib.total_messages() * 20 < o_e_cost.max(1),
        "nibble must do a small fraction of O(E)-per-iteration work"
    );

    // ---------------- summary ----------------
    println!("\n=== summary (rmat{scale}, {threads} threads) ===");
    let mut tab = gpop::bench::Table::new(&["app", "time", "iters", "throughput"]);
    tab.row(&[
        "pagerank(10)".into(),
        fmt::secs(pr_time),
        "10".into(),
        format!("{} edges/s", fmt::si(edges10 / pr_time)),
    ]);
    tab.row(&[
        "bfs".into(),
        fmt::secs(bfs_time),
        bfs_rep.n_iters().to_string(),
        format!("{} msgs/s", fmt::si(bfs_rep.total_messages() as f64 / bfs_time)),
    ]);
    tab.row(&[
        "cc".into(),
        fmt::secs(cc_time),
        cc_rep.n_iters().to_string(),
        format!("{} msgs/s", fmt::si(cc_rep.total_messages() as f64 / cc_time)),
    ]);
    tab.row(&[
        "sssp".into(),
        fmt::secs(sssp_time),
        sssp.n_iters().to_string(),
        format!("{} msgs/s", fmt::si(sssp.total_messages() as f64 / sssp_time)),
    ]);
    tab.row(&[
        "nibble".into(),
        fmt::secs(nib_time),
        nib.n_iters().to_string(),
        format!("support {}", nib.output.support),
    ]);
    tab.print();
    println!("\nall cross-checks PASSED");
}
