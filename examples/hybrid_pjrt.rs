//! Hybrid three-layer demo: the rust coordinator executing the
//! AOT-compiled JAX + Pallas PageRank via PJRT, cross-checked against
//! the native PPM engine.
//!
//! Layer map (DESIGN.md): L1 Pallas `spmv_block` (DC-mode gather as MXU
//! matmuls) → L2 JAX `pagerank_step`/`pagerank_run` → HLO text
//! artifacts → this rust binary loads + executes them. Python is not
//! running anywhere in this process.
//!
//! Run: `make artifacts && cargo run --release --example hybrid_pjrt`

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::PageRank;
use gpop::graph::gen;
use gpop::ppm::PpmConfig;
use gpop::runtime::{pjrt, PjrtRuntime};
use gpop::util::fmt;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = pjrt::default_artifacts_dir();
    let rt = PjrtRuntime::new(&dir)?;
    let m = rt.manifest.clone();
    println!(
        "PJRT platform: {} — artifacts: k={} q={} n={} ({} fused iters)",
        rt.platform(),
        m.k,
        m.q,
        m.n,
        m.iters
    );

    // Deterministic workload sized to the artifact shapes.
    let graph = gen::erdos_renyi(m.n, m.n * 8, 42);
    println!("workload: er({}, {})\n", m.n, graph.m());
    let (blocks, inv_deg) = pjrt::graph_to_blocks(&graph, m.k, m.q);
    let rank0 = vec![1.0f32 / m.n as f32; m.n];

    // --- compile (once per process; this is the paper's "preprocessing")
    let t = Instant::now();
    let exe = rt.pagerank()?;
    println!("compile artifacts: {}", fmt::secs(t.elapsed().as_secs_f64()));

    // --- single-step path
    let t = Instant::now();
    let mut rank = rank0.clone();
    for _ in 0..m.iters {
        rank = exe.step(&blocks, &rank, &inv_deg, 0.85)?;
    }
    let step_time = t.elapsed().as_secs_f64();
    println!("{} step() calls:    {}", m.iters, fmt::secs(step_time));

    // --- fused lax.scan path (one executable, iters baked in)
    let t = Instant::now();
    let fused = exe.run(&blocks, &rank0, &inv_deg, 0.85)?;
    let fused_time = t.elapsed().as_secs_f64();
    println!("1 fused run() call: {}", fmt::secs(fused_time));

    // --- native engine cross-check
    let session = EngineSession::new(graph, PpmConfig { threads: 4, ..Default::default() });
    let native = Runner::on(&session)
        .until(Convergence::MaxIters(m.iters))
        .run(PageRank::new(&session.graph(), 0.85));

    let err = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
    };
    let e_step = err(&rank, &native.output);
    let e_fused = err(&fused, &native.output);
    let e_paths = err(&rank, &fused);
    println!("\nmax |stepped - native| = {e_step:.3e}");
    println!("max |fused   - native| = {e_fused:.3e}");
    println!("max |stepped - fused|  = {e_paths:.3e}");
    assert!(e_step < 1e-4 && e_fused < 1e-4, "layer mismatch");
    println!("\nthree-layer numerics check PASSED");
    Ok(())
}
