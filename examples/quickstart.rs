//! Quickstart: the GPOP public API in ~40 lines.
//!
//! Builds a small social-network-like RMAT graph, runs PageRank and BFS
//! through the PPM engine, and prints the results — the "hello world"
//! of the framework.
//!
//! Run: `cargo run --release --example quickstart`

use gpop::apps;
use gpop::graph::gen;
use gpop::ppm::{Engine, PpmConfig};

fn main() {
    // 64K-vertex scale-free graph, Graph500 RMAT parameters.
    let graph = gen::rmat(16, Default::default(), false);
    println!("graph: {} vertices, {} edges", graph.n(), graph.m());

    // The engine picks k (partition count) so each partition's vertex
    // data fits the 256 KB L2 budget, per the paper's §3.1 heuristic.
    let config = PpmConfig { threads: 4, ..Default::default() };
    let mut engine = Engine::new(graph, config);
    println!("partitions: k = {} (q = {})", engine.parts().k(), engine.parts().q());

    // --- PageRank: 10 iterations, all vertices active, DC-mode heavy.
    let pr = apps::pagerank::run(&mut engine, 0.85, 10);
    let mut top: Vec<(usize, f32)> = pr.rank.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop-5 PageRank:");
    for (v, r) in top.iter().take(5) {
        println!("  vertex {v:>6}: {r:.6}");
    }
    let dc_parts: usize = pr.iters.iter().map(|i| i.dc_parts).sum();
    let sc_parts: usize = pr.iters.iter().map(|i| i.sc_parts).sum();
    println!("mode choices: {dc_parts} DC vs {sc_parts} SC partition-scatters");

    // --- BFS from vertex 0: frontier-driven, SC-mode heavy.
    let bfs = apps::bfs::run(&mut engine, 0);
    println!(
        "\nBFS: reached {} vertices in {} iterations ({} messages)",
        bfs.n_reached(),
        bfs.stats.n_iters(),
        bfs.stats.total_messages()
    );
}
