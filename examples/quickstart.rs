//! Quickstart: the GPOP public API in ~50 lines.
//!
//! Builds a small social-network-like RMAT graph, opens ONE
//! `EngineSession` (pre-processing paid once), and serves three queries
//! through the fluent `Runner` — PageRank to an L1 tolerance, a BFS,
//! and a 4-root BFS batch — the "hello world" of the framework.
//!
//! Run: `cargo run --release --example quickstart`

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{bfs, Bfs, PageRank};
use gpop::graph::gen;
use gpop::ppm::PpmConfig;

fn main() {
    // 64K-vertex scale-free graph, Graph500 RMAT parameters.
    let graph = gen::rmat(16, Default::default(), false);
    println!("graph: {} vertices, {} edges", graph.n(), graph.m());

    // The session picks k (partition count) so each partition's vertex
    // data fits the 256 KB L2 budget (paper §3.1), builds the bin/PNG
    // layout ONCE, and shares it across every query that follows.
    let session = EngineSession::new(graph, PpmConfig { threads: 4, ..Default::default() });
    println!("partitions: k = {} (q = {})", session.parts().k(), session.parts().q());
    let n = session.graph().n();

    // --- PageRank: run to a numeric tolerance (bounded at 50 iters).
    let pr = Runner::on(&session)
        .until(Convergence::L1Norm(1e-7).or_max_iters(50))
        .run(PageRank::new(session.graph(), 0.85));
    let mut top: Vec<(usize, f32)> = pr.output.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop-5 PageRank ({} iters, converged: {}):", pr.n_iters(), pr.converged);
    for (v, r) in top.iter().take(5) {
        println!("  vertex {v:>6}: {r:.6}");
    }
    println!("mode choices: {} DC vs {} SC partition-scatters", pr.dc_parts(), pr.sc_parts());

    // --- BFS from vertex 0: frontier-driven, SC-mode heavy. Reuses the
    // session's cached layout AND the engine PageRank just returned.
    let report = Runner::on(&session).run(Bfs::new(n, 0));
    println!(
        "\nBFS: reached {} vertices in {} iterations ({} messages)",
        bfs::n_reached(&report.output),
        report.n_iters(),
        report.total_messages()
    );

    // --- Batched multi-query: 4 BFS roots against one checked-out
    // engine — the serving pattern (partition metadata amortized).
    let roots = [0u32, 1, 2, 3];
    let reports = Runner::on(&session).run_batch(roots.map(|r| Bfs::new(n, r)));
    println!("\nbatched BFS roots:");
    for (root, rep) in roots.iter().zip(&reports) {
        println!("  root {root}: reached {}", bfs::n_reached(&rep.output));
    }
}
