//! Quickstart: the GPOP public API in ~70 lines.
//!
//! Builds a small social-network-like RMAT graph, opens ONE
//! `EngineSession` (pre-processing paid once), and serves queries
//! through the fluent `Runner` — PageRank to an L1 tolerance, a BFS, a
//! 4-root BFS batch, and a one-pass SSSP-with-parents on the weighted
//! variant (a 2-lane `(f32, u32)` message: typed payloads need no
//! bit twiddling).
//!
//! Run: `cargo run --release --example quickstart`

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{bfs, sssp_parents::NO_PARENT, Bfs, PageRank, SsspParents};
use gpop::graph::gen;
use gpop::ppm::PpmConfig;

fn main() {
    // 64K-vertex scale-free graph, Graph500 RMAT parameters.
    let graph = gen::rmat(16, Default::default(), false);
    println!("graph: {} vertices, {} edges", graph.n(), graph.m());

    // The session picks k (partition count) so each partition's vertex
    // data fits the 256 KB L2 budget (paper §3.1), builds the bin/PNG
    // layout ONCE, and shares it across every query that follows.
    let session = EngineSession::new(graph, PpmConfig { threads: 4, ..Default::default() });
    println!("partitions: k = {} (q = {})", session.parts().k(), session.parts().q());
    let n = session.graph().n();

    // --- PageRank: run to a numeric tolerance (bounded at 50 iters).
    let pr = Runner::on(&session)
        .until(Convergence::L1Norm(1e-7).or_max_iters(50))
        .run(PageRank::new(&session.graph(), 0.85));
    let mut top: Vec<(usize, f32)> = pr.output.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop-5 PageRank ({} iters, converged: {}):", pr.n_iters(), pr.converged);
    for (v, r) in top.iter().take(5) {
        println!("  vertex {v:>6}: {r:.6}");
    }
    println!("mode choices: {} DC vs {} SC partition-scatters", pr.dc_parts(), pr.sc_parts());

    // --- BFS from vertex 0: frontier-driven, SC-mode heavy. Reuses the
    // session's cached layout AND the engine PageRank just returned.
    let report = Runner::on(&session).run(Bfs::new(n, 0));
    println!(
        "\nBFS: reached {} vertices in {} iterations ({} messages)",
        bfs::n_reached(&report.output),
        report.n_iters(),
        report.total_messages()
    );

    // --- Batched multi-query: 4 BFS roots against one checked-out
    // engine — the serving pattern (partition metadata amortized).
    let roots = [0u32, 1, 2, 3];
    let reports = Runner::on(&session).run_batch(roots.map(|r| Bfs::new(n, r)));
    println!("\nbatched BFS roots:");
    for (root, rep) in roots.iter().zip(&reports) {
        println!("  root {root}: reached {}", bfs::n_reached(&rep.output));
    }

    // --- One-pass SSSP with parents on a weighted session: the message
    // is (candidate distance, proposing parent) — two lanes traveling
    // together, so the shortest-path tree needs no second sweep.
    let wgraph = gen::with_uniform_weights(&session.graph(), 1.0, 4.0, 7);
    let wsession = EngineSession::new(wgraph, PpmConfig { threads: 4, ..Default::default() });
    let sp = Runner::on(&wsession).run(SsspParents::new(n, 0));
    let tree_edges =
        sp.output.parent.iter().enumerate().filter(|&(v, &p)| p != NO_PARENT && p as usize != v);
    println!(
        "\nSSSP+parents from 0: reached {} vertices, {} tree edges, {} iterations",
        sp.output.n_reached(),
        tree_edges.count(),
        sp.n_iters()
    );
    if let Some(path) = sp.output.path_to((n - 1) as u32) {
        println!("  shortest path to {}: {} hops", n - 1, path.len() - 1);
    }
}
