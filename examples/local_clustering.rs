//! Strongly-local clustering with Nibble — the paper's showcase for
//! selective frontier continuity (§4/§5).
//!
//! Demonstrates (a) that per-run work is proportional to the cluster
//! neighborhood, not the graph (`O(E)` init amortized across runs on
//! one engine), and (b) a conductance sweep over the Nibble / ACL
//! PageRank-Nibble embeddings to extract an actual cluster.
//!
//! Run: `cargo run --release --example local_clustering`

use gpop::api::{Convergence, EngineSession, Runner};
use gpop::apps::{Nibble, PageRankNibble};
use gpop::graph::{Graph, GraphBuilder};
use gpop::ppm::PpmConfig;
use gpop::util::fmt;
use gpop::VertexId;

/// `n_comms` communities of `csize` vertices joined in a ring by narrow
/// bridges: the classic local-clustering testbed (planted partition).
fn planted_communities(n_comms: usize, csize: usize, seed: u64) -> Graph {
    let mut rng = gpop::util::rng::Rng::new(seed);
    let n = n_comms * csize;
    let mut b = GraphBuilder::new().with_n(n).symmetrize().dedup();
    // Dense-ish inside each community.
    for comm in 0..n_comms {
        let base = (comm * csize) as u32;
        for _ in 0..csize * 8 {
            let u = base + rng.below(csize as u64) as u32;
            let v = base + rng.below(csize as u64) as u32;
            if u != v {
                b.add(u, v);
            }
        }
    }
    // A few bridge edges between consecutive communities.
    for comm in 0..n_comms {
        let a = (comm * csize) as u32;
        let c = (((comm + 1) % n_comms) * csize) as u32;
        for i in 0..4u32 {
            b.add(a + i, c + i);
        }
    }
    b.build()
}

/// Sweep cut: order vertices by deg-normalized score, return the prefix
/// with minimum conductance.
fn sweep_conductance(g: &Graph, score: &[f32]) -> (Vec<VertexId>, f64) {
    let mut order: Vec<VertexId> = (0..g.n() as VertexId)
        .filter(|&v| score[v as usize] > 0.0)
        .collect();
    order.sort_by(|&a, &b| {
        let sa = score[a as usize] / g.out_degree(a).max(1) as f32;
        let sb = score[b as usize] / g.out_degree(b).max(1) as f32;
        sb.total_cmp(&sa)
    });
    let total_vol: u64 = (0..g.n() as VertexId).map(|v| g.out_degree(v) as u64).sum();
    let mut in_set = vec![false; g.n()];
    let mut vol = 0u64;
    let mut cut = 0i64;
    let mut best = (1, f64::INFINITY);
    for (i, &v) in order.iter().enumerate() {
        in_set[v as usize] = true;
        vol += g.out_degree(v) as u64;
        for &u in g.out().neighbors(v) {
            // Edge v-u: enters the cut if u outside, leaves if inside.
            cut += if in_set[u as usize] { -1 } else { 1 };
        }
        let denom = vol.min(total_vol - vol).max(1) as f64;
        let phi = cut.max(0) as f64 / denom;
        if phi < best.1 {
            best = (i + 1, phi);
        }
    }
    (order[..best.0].to_vec(), best.1)
}

fn main() {
    let (n_comms, csize) = (10, 1000);
    let half = csize; // size of the seed community
    let graph = std::sync::Arc::new(planted_communities(n_comms, csize, 1234));
    println!(
        "planted graph: {} communities x {} vertices — {} vertices, {} edges, bridge width 4",
        n_comms,
        csize,
        graph.n(),
        graph.m()
    );

    // ONE session: pre-processing cost paid once, amortized over many
    // local runs (§5: "the initialization cost can be amortized"). The
    // seed-sweep below goes through `run_batch`, so all three queries
    // also share one checked-out engine.
    let t0 = std::time::Instant::now();
    let session =
        EngineSession::new(graph.clone(), PpmConfig { threads: 4, ..Default::default() });
    println!("session pre-processing: {}\n", fmt::secs(t0.elapsed().as_secs_f64()));

    // --- Nibble from seeds in community 0; work must stay local.
    println!("-- Nibble (selective continuity via initFunc) --");
    let iters = 30;
    let seeds = [0u32, 7, 100];
    let t = std::time::Instant::now();
    let reports = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(iters))
        .run_batch(seeds.map(|s| Nibble::new(&graph, 2e-5, &[s])));
    let batch_time = t.elapsed().as_secs_f64();
    for (seed, res) in seeds.iter().zip(&reports) {
        let in_comm0 = res.output.pr.iter().take(half).filter(|&&x| x > 0.0).count();
        println!(
            "seed {seed:>4}: support {:>5} ({} in seed community) msgs {:>8}",
            res.output.support,
            in_comm0,
            res.total_messages(),
        );
        // Work-efficiency: an O(E)-per-iteration framework would stream
        // iters * m edges; Nibble must do a fraction of that.
        assert!(
            res.total_messages() < (iters * graph.m()) as u64 / 5,
            "local run must beat O(E)-per-iteration engines"
        );
    }
    println!("batch of {} local runs in {}", seeds.len(), fmt::secs(batch_time));

    // --- PageRank-Nibble + sweep: recover the planted community.
    // eps keeps the diffusion support within ~1 community so the sweep
    // cannot drift around the ring (ACL: support ~ 1/(eps * vol)).
    println!("\n-- PageRank-Nibble + conductance sweep --");
    let res = Runner::on(&session)
        .until(Convergence::FrontierEmpty.or_max_iters(300))
        .run(PageRankNibble::new(&graph, 0.2, 1e-5, &[0]));
    let (cluster, phi) = sweep_conductance(&graph, &res.output.p);
    let in_comm0 = cluster.iter().filter(|&&v| (v as usize) < half).count();
    println!(
        "cluster: {} vertices, conductance {:.4}, purity {:.1}%",
        cluster.len(),
        phi,
        100.0 * in_comm0 as f64 / cluster.len() as f64
    );
    assert!(
        in_comm0 as f64 / cluster.len() as f64 > 0.9,
        "sweep should recover the planted community"
    );
    println!("\ncommunity recovery PASSED");
}
